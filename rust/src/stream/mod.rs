//! Streaming graph subsystem: incremental GRF maintenance for dynamic
//! graphs.
//!
//! The paper's pipeline assumes a frozen graph — a single `add_edge`
//! would force a full O(N^{3/2}) walk resample and feature rebuild.
//! But GRF walks are **node-local**: an edge delta touching (u, v)
//! only changes the transition behaviour *at* u and v, so a walk whose
//! trajectory never stepped through either endpoint replays
//! bit-identically under its own RNG stream
//! ([`crate::walks::walk_rng`]). [`StreamingFeatures`] exploits this:
//!
//! * every walk `(node, t)` is independently seeded, and the sampler
//!   emits a **visit index** `visit[j] = [(node, t), ...]` of the walks
//!   that stepped through `j` ([`crate::walks::sample_components_indexed`]);
//! * a [`GraphDelta`] invalidates exactly `visit[u] ∪ visit[v]`; only
//!   those walks are re-run, and only the rows of the affected *source*
//!   nodes are rebuilt ([`crate::walks::rows_from_walks`] — the same
//!   code path the full sampler uses, which is what makes the
//!   incremental update **bit-identical** to a from-scratch rebuild of
//!   the mutated graph under the same per-walk seeds);
//! * patched rows live in a **delta row-store** overlaying the
//!   compacted base CSRs; when the overlay exceeds its threshold the
//!   store compacts (one O(nnz) splice per matrix) and re-runs the
//!   [`crate::sparse::FeatureLayout`] selection (`to_ell_auto` policy)
//!   on the fresh Φ.
//!
//! Cost per delta: O(|visit[u]| + |visit[v]|) walk re-runs plus the
//! affected-row rebuild — independent of N for bounded-degree graphs
//! (Theorem 1 bounds the visit counts w.h.p.), against O(N · n_walks)
//! for the full resample. See `benches/hotpath.rs` (`stream_delta` vs
//! `stream_full_rebuild` rows).

use crate::graph::Graph;
use crate::sparse::{Csr, Ell, FeatureLayout};
use crate::walks::{
    resample_walk, rows_from_walks, sample_components_indexed, NodeWalks,
    WalkComponents, WalkConfig,
};
use std::collections::{BTreeMap, BTreeSet};

/// One mutation of the served graph.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphDelta {
    /// Add (or reinforce: weights sum) the undirected edge (u, v).
    AddEdge { u: usize, v: usize, w: f64 },
    /// Remove the undirected edge (u, v).
    RemoveEdge { u: usize, v: usize },
    /// Append an isolated node.
    AddNode,
}

/// What a delta actually touched — the incrementality contract.
#[derive(Clone, Debug)]
pub struct DeltaSummary {
    /// Walks that were re-run, exactly `visit[u] ∪ visit[v]` of the
    /// pre-delta visit index (all walks of the new node for
    /// [`GraphDelta::AddNode`]).
    pub resampled: Vec<(u32, u32)>,
    /// Source rows whose feature rows were rebuilt (sorted).
    pub affected_rows: Vec<u32>,
    /// Id of the appended node, for [`GraphDelta::AddNode`].
    pub added_node: Option<usize>,
    /// Whether this delta triggered an overlay compaction.
    pub compacted: bool,
}

/// A patched row: per-length component rows + the combined Φ row.
#[derive(Clone, Debug)]
struct RowPatch {
    per_len: Vec<(Vec<u32>, Vec<f64>)>,
    phi: (Vec<u32>, Vec<f64>),
}

/// Incrementally maintained GRF features over a mutable graph.
///
/// Holds the graph, the per-walk deposit store, the visit index, the
/// compacted base matrices (per-length components and the combined Φ
/// under a fixed modulation `f`), and the delta row-store overlay.
/// [`StreamingFeatures::apply_delta`] is the only mutation entry point;
/// the correctness anchor (property-tested below) is that the state
/// after any delta sequence is bit-identical to
/// [`StreamingFeatures::new`] on the mutated graph.
pub struct StreamingFeatures {
    graph: Graph,
    cfg: WalkConfig,
    seed: u64,
    /// Modulation coefficients of the maintained Φ = Σ_l f_l C_l.
    f: Vec<f64>,
    /// Current weighted degrees (empty unless `cfg.normalize`).
    norm_deg: Vec<f64>,
    store: Vec<NodeWalks>,
    visit: Vec<Vec<(u32, u32)>>,
    /// Compacted per-length component matrices.
    base: Vec<Csr>,
    /// Compacted combined feature matrix Φ(f).
    phi_base: Csr,
    /// Delta row-store: rows rebuilt since the last compaction.
    overlay: BTreeMap<u32, RowPatch>,
    /// Compact when the overlay holds at least this many rows.
    compact_threshold: usize,
    /// Layout policy re-run on Φ at every compaction.
    layout: FeatureLayout,
    /// ELL operand selected at the last compaction (None = CSR or
    /// policy rejection); stale while the overlay is non-empty.
    phi_ell: Option<Ell>,
    /// Lifetime counters (observability for the server stats op).
    pub deltas_applied: usize,
    pub walks_resampled_total: usize,
    pub compactions: usize,
}

/// Combine per-length rows into the Φ row: gather `(col, f_l · v)` in
/// length order, sort by column, merge runs. Shared by the full build
/// and the patcher so both produce bitwise-equal rows. Zero
/// coefficients still contribute pattern entries (the row pattern is
/// the union pattern, as in [`crate::walks::CombinedFeatures`]).
fn combine_row(per_len: &[(Vec<u32>, Vec<f64>)], f: &[f64]) -> (Vec<u32>, Vec<f64>) {
    debug_assert_eq!(per_len.len(), f.len());
    let mut ent: Vec<(u32, f64)> = Vec::new();
    for ((cols, vals), &fl) in per_len.iter().zip(f) {
        for (c, v) in cols.iter().zip(vals) {
            ent.push((*c, fl * v));
        }
    }
    ent.sort_unstable_by_key(|&(c, _)| c);
    let mut cols = Vec::with_capacity(ent.len());
    let mut vals = Vec::with_capacity(ent.len());
    let mut k = 0;
    while k < ent.len() {
        let c = ent[k].0;
        let mut v = 0.0;
        while k < ent.len() && ent[k].0 == c {
            v += ent[k].1;
            k += 1;
        }
        cols.push(c);
        vals.push(v);
    }
    (cols, vals)
}

/// Assemble Φ = Σ_l f_l C_l row-by-row through [`combine_row`] — the
/// single constructor shared by the fresh build and the modulation
/// swap (the bit-identity between those paths depends on it).
fn build_phi(base: &[Csr], n_cols: usize, f: &[f64]) -> Csr {
    let n = base.first().map(|c| c.n_rows).unwrap_or(0);
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    let mut scratch: Vec<(Vec<u32>, Vec<f64>)> = Vec::with_capacity(f.len());
    for r in 0..n {
        scratch.clear();
        for c in base {
            let (rc, rv) = c.row(r);
            scratch.push((rc.to_vec(), rv.to_vec()));
        }
        let (pc, pv) = combine_row(&scratch, f);
        cols.extend_from_slice(&pc);
        vals.extend_from_slice(&pv);
        offsets.push(cols.len());
    }
    Csr { n_rows: n, n_cols, offsets, cols, vals }
}

impl StreamingFeatures {
    /// Full (parallel) build on a static graph — also the reference the
    /// incremental path is tested against.
    pub fn new(graph: Graph, cfg: WalkConfig, f: Vec<f64>, seed: u64) -> StreamingFeatures {
        assert_eq!(f.len(), cfg.max_len + 1, "modulation length != l_max+1");
        let n = graph.num_nodes();
        let iw = sample_components_indexed(&graph, &cfg, seed);
        let norm_deg: Vec<f64> = if cfg.normalize {
            (0..n).map(|i| graph.weighted_degree(i).max(1e-12)).collect()
        } else {
            Vec::new()
        };
        let base = iw.components.c;
        let phi_base = build_phi(&base, n, &f);
        let layout = FeatureLayout::Auto;
        let phi_ell = phi_base.select_ell(layout);
        StreamingFeatures {
            graph,
            cfg,
            seed,
            f,
            norm_deg,
            store: iw.store,
            visit: iw.visit,
            base,
            phi_base,
            overlay: BTreeMap::new(),
            compact_threshold: (n / 8).max(64),
            layout,
            phi_ell,
            deltas_applied: 0,
            walks_resampled_total: 0,
            compactions: 0,
        }
    }

    pub fn n(&self) -> usize {
        self.graph.num_nodes()
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn config(&self) -> &WalkConfig {
        &self.cfg
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn modulation(&self) -> &[f64] {
        &self.f
    }

    /// Rows currently held in the delta row-store.
    pub fn overlay_rows(&self) -> usize {
        self.overlay.len()
    }

    /// Overlay size that triggers compaction (default `max(64, n/8)`).
    pub fn set_compact_threshold(&mut self, rows: usize) {
        self.compact_threshold = rows.max(1);
    }

    /// The layout policy re-run on Φ at each compaction.
    pub fn set_layout(&mut self, layout: FeatureLayout) {
        self.layout = layout;
        self.phi_ell = self.phi_base.select_ell(layout);
    }

    /// ELL operand of the compacted Φ (as of the last compaction;
    /// `None` when the policy kept CSR or the overlay pre-empts it).
    pub fn phi_ell(&self) -> Option<&Ell> {
        if self.overlay.is_empty() {
            self.phi_ell.as_ref()
        } else {
            None
        }
    }

    /// All walks whose trajectories stepped through any of `nodes` —
    /// the invalidation set of a delta touching those endpoints.
    pub fn visiting_walks(&self, nodes: &[usize]) -> BTreeSet<(u32, u32)> {
        let mut out = BTreeSet::new();
        for &i in nodes {
            if i < self.visit.len() {
                out.extend(self.visit[i].iter().copied());
            }
        }
        out
    }

    /// Current content of component row `r` at length `l` (overlay wins
    /// over base; rows beyond the base are empty until patched).
    pub fn component_row(&self, l: usize, r: usize) -> (Vec<u32>, Vec<f64>) {
        if let Some(p) = self.overlay.get(&(r as u32)) {
            p.per_len[l].clone()
        } else if r < self.base[l].n_rows {
            let (c, v) = self.base[l].row(r);
            (c.to_vec(), v.to_vec())
        } else {
            (Vec::new(), Vec::new())
        }
    }

    /// Materialise the current per-length components (base + overlay).
    pub fn components(&self) -> WalkComponents {
        let n = self.n();
        let c = (0..self.base.len())
            .map(|l| {
                let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
                    .overlay
                    .iter()
                    .map(|(&r, p)| (r, p.per_len[l].clone()))
                    .collect();
                self.base[l].with_replaced_rows(n, n, &patches)
            })
            .collect();
        WalkComponents::new(c)
    }

    /// Materialise the current Φ (base + overlay).
    pub fn phi_snapshot(&self) -> Csr {
        let n = self.n();
        if self.overlay.is_empty() && self.phi_base.n_rows == n {
            return self.phi_base.clone();
        }
        let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
            .overlay
            .iter()
            .map(|(&r, p)| (r, p.phi.clone()))
            .collect();
        self.phi_base.with_replaced_rows(n, n, &patches)
    }

    /// Swap the modulation and recombine every Φ row (components are
    /// untouched — walks don't depend on `f`). O(nnz).
    pub fn set_modulation(&mut self, f: Vec<f64>) {
        assert_eq!(f.len(), self.cfg.max_len + 1);
        self.f = f;
        // Rebuild phi_base from the base components, then the overlay
        // Φ rows from their per-length patches.
        self.phi_base = build_phi(&self.base, self.phi_base.n_cols, &self.f);
        let f = self.f.clone();
        for p in self.overlay.values_mut() {
            p.phi = combine_row(&p.per_len, &f);
        }
        self.phi_ell = self.phi_base.select_ell(self.layout);
    }

    /// Apply one graph mutation: resample exactly the invalidated
    /// walks, rebuild the affected rows into the overlay, maybe
    /// compact. Errors leave the state untouched.
    pub fn apply_delta(&mut self, delta: &GraphDelta) -> Result<DeltaSummary, String> {
        let n = self.n();
        let invalid = match *delta {
            GraphDelta::AddEdge { u, v, w } => {
                if u >= n || v >= n {
                    return Err(format!("add_edge ({u},{v}) out of range (n={n})"));
                }
                if !w.is_finite() || w <= 0.0 {
                    return Err(format!("add_edge weight {w} must be finite and > 0"));
                }
                let invalid = self.visiting_walks(&[u, v]);
                self.graph.add_edge(u, v, w);
                self.update_norm_deg(&[u, v]);
                invalid
            }
            GraphDelta::RemoveEdge { u, v } => {
                if u >= n || v >= n {
                    return Err(format!("remove_edge ({u},{v}) out of range (n={n})"));
                }
                let invalid = self.visiting_walks(&[u, v]);
                if !self.graph.remove_edge(u, v) {
                    return Err(format!("remove_edge ({u},{v}): no such edge"));
                }
                self.update_norm_deg(&[u, v]);
                invalid
            }
            GraphDelta::AddNode => {
                let id = self.graph.add_node();
                if self.cfg.normalize {
                    self.norm_deg
                        .push(self.graph.weighted_degree(id).max(1e-12));
                }
                self.visit.push(Vec::new());
                self.store.push(NodeWalks {
                    offsets: vec![0],
                    deposits: Vec::new(),
                });
                (0..self.cfg.n_walks)
                    .map(|t| (id as u32, t as u32))
                    .collect()
            }
        };
        let added_node = match delta {
            GraphDelta::AddNode => Some(self.n() - 1),
            _ => None,
        };
        let mut summary = self.resample(&invalid);
        summary.added_node = added_node;
        self.deltas_applied += 1;
        self.walks_resampled_total += summary.resampled.len();
        if self.overlay.len() >= self.compact_threshold {
            self.compact();
            summary.compacted = true;
        }
        Ok(summary)
    }

    /// Merge the overlay into the base matrices and re-run the
    /// `to_ell_auto` layout policy on the fresh Φ.
    pub fn compact(&mut self) {
        let n = self.n();
        for l in 0..self.base.len() {
            let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
                .overlay
                .iter()
                .map(|(&r, p)| (r, p.per_len[l].clone()))
                .collect();
            self.base[l] = self.base[l].with_replaced_rows(n, n, &patches);
        }
        let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
            .overlay
            .iter()
            .map(|(&r, p)| (r, p.phi.clone()))
            .collect();
        self.phi_base = self.phi_base.with_replaced_rows(n, n, &patches);
        self.overlay.clear();
        self.phi_ell = self.phi_base.select_ell(self.layout);
        self.compactions += 1;
    }

    fn update_norm_deg(&mut self, nodes: &[usize]) {
        if self.cfg.normalize {
            for &i in nodes {
                self.norm_deg[i] = self.graph.weighted_degree(i).max(1e-12);
            }
        }
    }

    /// Re-run the given walks on the current graph, rebuild the rows of
    /// their source nodes, and stage them in the overlay.
    fn resample(&mut self, invalid: &BTreeSet<(u32, u32)>) -> DeltaSummary {
        let n_len = self.cfg.max_len + 1;
        let inv_n = 1.0 / self.cfg.n_walks as f64;
        let mut by_node: BTreeMap<u32, BTreeSet<u32>> = BTreeMap::new();
        for &(i, t) in invalid {
            by_node.entry(i).or_default().insert(t);
        }
        let mut affected_rows = Vec::with_capacity(by_node.len());
        let mut seen: Vec<u32> = Vec::new();
        for (&i, ts) in &by_node {
            let iu = i as usize;
            let old = std::mem::take(&mut self.store[iu]);
            let mut nw = NodeWalks {
                offsets: Vec::with_capacity(self.cfg.n_walks + 1),
                deposits: Vec::new(),
            };
            nw.offsets.push(0);
            for t in 0..self.cfg.n_walks {
                let start = nw.deposits.len();
                if ts.contains(&(t as u32)) {
                    // Drop the walk's old visit entries...
                    if t < old.n_walks() {
                        seen.clear();
                        seen.extend(old.walk(t).iter().map(|&(j, _)| j));
                        seen.sort_unstable();
                        seen.dedup();
                        for &j in &seen {
                            let lst = &mut self.visit[j as usize];
                            if let Some(p) =
                                lst.iter().position(|&e| e == (i, t as u32))
                            {
                                lst.swap_remove(p);
                            }
                        }
                    }
                    // ...re-run it under its own stream...
                    resample_walk(
                        &self.graph,
                        &self.cfg,
                        &self.norm_deg,
                        iu,
                        t,
                        self.seed,
                        &mut nw.deposits,
                    );
                    // ...and index the new trajectory.
                    seen.clear();
                    seen.extend(nw.deposits[start..].iter().map(|&(j, _)| j));
                    seen.sort_unstable();
                    seen.dedup();
                    for &j in &seen {
                        self.visit[j as usize].push((i, t as u32));
                    }
                } else {
                    nw.deposits.extend_from_slice(old.walk(t));
                }
                nw.offsets.push(nw.deposits.len() as u32);
            }
            let per_len = rows_from_walks(&nw, n_len, inv_n);
            let phi = combine_row(&per_len, &self.f);
            self.store[iu] = nw;
            self.overlay.insert(i, RowPatch { per_len, phi });
            affected_rows.push(i);
        }
        DeltaSummary {
            resampled: invalid.iter().copied().collect(),
            affected_rows,
            added_node: None,
            compacted: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    fn random_graph(rng: &mut Rng, n: usize, p: f64) -> (Graph, Vec<(u32, u32, f64)>) {
        let mut edges = Vec::new();
        for i in 0..n as u32 {
            for j in (i + 1)..n as u32 {
                if rng.bernoulli(p) {
                    edges.push((i, j, 0.2 + 0.8 * rng.uniform()));
                }
            }
        }
        (Graph::from_edges(n, &edges), edges)
    }

    fn test_cfg(rng: &mut Rng) -> WalkConfig {
        WalkConfig {
            n_walks: 6 + rng.below(6),
            p_halt: 0.15,
            max_len: 3,
            reweight: true,
            normalize: rng.bernoulli(0.5),
            threads: 1,
        }
    }

    fn random_delta(g: &Graph, rng: &mut Rng) -> GraphDelta {
        let n = g.num_nodes();
        match rng.below(4) {
            0 => GraphDelta::AddNode,
            1 => {
                // Remove a random existing edge if any.
                let with_deg: Vec<usize> =
                    (0..n).filter(|&i| g.degree(i) > 0).collect();
                if with_deg.is_empty() {
                    GraphDelta::AddNode
                } else {
                    let u = with_deg[rng.below(with_deg.len())];
                    let v = g.neighbors(u)[rng.below(g.degree(u))] as usize;
                    GraphDelta::RemoveEdge { u, v }
                }
            }
            _ => {
                let u = rng.below(n);
                let v = rng.below(n);
                GraphDelta::AddEdge { u, v, w: 0.2 + 0.8 * rng.uniform() }
            }
        }
    }

    /// Acceptance property: for random graphs, random deltas, and fixed
    /// seeds, the incremental state is bit-identical to a from-scratch
    /// rebuild of the mutated graph, and only walks that visited the
    /// delta endpoints were resampled.
    #[test]
    fn incremental_matches_full_rebuild_bitwise() {
        proptest(8, |rng| {
            let n = 8 + rng.below(10);
            let (g, _) = random_graph(rng, n, 0.25);
            let cfg = test_cfg(rng);
            let f = vec![1.0, 0.6, 0.3, 0.1];
            let seed = rng.next_u64();
            let mut s =
                StreamingFeatures::new(g.clone(), cfg.clone(), f.clone(), seed);
            // Exercise both the overlay path and per-delta compaction.
            let threshold = if rng.bernoulli(0.5) { 1 } else { usize::MAX };
            s.set_compact_threshold(threshold);
            let mut g2 = g;
            for step in 0..5 {
                let delta = random_delta(&g2, rng);
                // Expected invalidation set from the PRE-delta index.
                let expect: BTreeSet<(u32, u32)> = match delta {
                    GraphDelta::AddEdge { u, v, .. }
                    | GraphDelta::RemoveEdge { u, v } => {
                        s.visiting_walks(&[u, v])
                    }
                    GraphDelta::AddNode => (0..cfg.n_walks)
                        .map(|t| (g2.num_nodes() as u32, t as u32))
                        .collect(),
                };
                // Mirror the delta on the reference graph.
                match delta {
                    GraphDelta::AddEdge { u, v, w } => g2.add_edge(u, v, w),
                    GraphDelta::RemoveEdge { u, v } => {
                        g2.remove_edge(u, v);
                    }
                    GraphDelta::AddNode => {
                        g2.add_node();
                    }
                }
                let sum = s.apply_delta(&delta).unwrap();
                let got: BTreeSet<(u32, u32)> =
                    sum.resampled.iter().copied().collect();
                prop_assert!(
                    got == expect,
                    "step {step}: resampled {got:?} != visit-index set {expect:?}"
                );
                let full = StreamingFeatures::new(
                    g2.clone(),
                    cfg.clone(),
                    f.clone(),
                    seed,
                );
                prop_assert!(
                    s.phi_snapshot() == full.phi_snapshot(),
                    "step {step} ({delta:?}): Φ not bit-identical to rebuild"
                );
                let (a, b) = (s.components().c, full.components().c);
                for l in 0..a.len() {
                    prop_assert!(
                        a[l] == b[l],
                        "step {step}: component {l} not bit-identical"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn delta_roundtrip_restores_state_bitwise() {
        // add_edge followed by remove_edge restores the graph, so the
        // resampled walks rerun their original trajectories and Φ must
        // come back bit-identical. A path graph guarantees (0, 9) is
        // initially absent.
        let edges: Vec<(u32, u32, f64)> =
            (0..13).map(|i| (i, i + 1, 1.0)).collect();
        let g = Graph::from_edges(14, &edges);
        let cfg = WalkConfig { n_walks: 8, max_len: 3, threads: 1, ..Default::default() };
        let f = vec![1.0, 0.5, 0.25, 0.125];
        let mut s = StreamingFeatures::new(g, cfg, f, 42);
        s.set_compact_threshold(usize::MAX);
        let before = s.phi_snapshot();
        s.apply_delta(&GraphDelta::AddEdge { u: 0, v: 9, w: 0.7 }).unwrap();
        assert!(s.phi_snapshot() != before, "delta should change Φ");
        s.apply_delta(&GraphDelta::RemoveEdge { u: 0, v: 9 }).unwrap();
        assert!(s.phi_snapshot() == before, "roundtrip must restore Φ bitwise");
    }

    #[test]
    fn add_node_rows_and_dimensions() {
        let mut rng = Rng::new(3);
        let (g, _) = random_graph(&mut rng, 10, 0.3);
        let cfg = WalkConfig { n_walks: 5, max_len: 2, threads: 1, ..Default::default() };
        let f = vec![2.0, 0.5, 0.25];
        let mut s = StreamingFeatures::new(g, cfg, f, 7);
        let sum = s.apply_delta(&GraphDelta::AddNode).unwrap();
        assert_eq!(sum.added_node, Some(10));
        assert_eq!(sum.resampled.len(), 5);
        let phi = s.phi_snapshot();
        assert_eq!(phi.n_rows, 11);
        assert_eq!(phi.n_cols, 11);
        // Isolated node: every walk deposits load 1.0 at l=0 only, so
        // its Φ row is exactly f_0 at the diagonal.
        let (cols, vals) = phi.row(10);
        assert_eq!(cols, &[10u32]);
        assert!((vals[0] - 2.0).abs() < 1e-12);
        // The new node can then be wired in.
        s.apply_delta(&GraphDelta::AddEdge { u: 10, v: 0, w: 1.0 }).unwrap();
        assert!(s.phi_snapshot().row(10).0.len() >= 1);
    }

    #[test]
    fn compaction_preserves_state_and_reselects_layout() {
        let mut rng = Rng::new(9);
        let (g, _) = random_graph(&mut rng, 16, 0.25);
        let cfg = WalkConfig { n_walks: 6, max_len: 3, threads: 1, ..Default::default() };
        let f = vec![1.0, 0.5, 0.25, 0.125];
        let mut s = StreamingFeatures::new(g, cfg, f, 5);
        s.set_compact_threshold(usize::MAX);
        for k in 0..4 {
            s.apply_delta(&GraphDelta::AddEdge { u: k, v: k + 5, w: 0.5 }).unwrap();
        }
        assert!(s.overlay_rows() > 0);
        let phi_overlay = s.phi_snapshot();
        let comps_overlay = s.components();
        s.compact();
        assert_eq!(s.overlay_rows(), 0);
        assert!(s.phi_snapshot() == phi_overlay, "compaction changed Φ");
        let comps = s.components();
        for l in 0..comps.c.len() {
            assert!(comps.c[l] == comps_overlay.c[l], "compaction changed C_{l}");
        }
        assert_eq!(s.compactions, 1);
        // Layout policy re-ran: under Auto on these near-uniform rows
        // it must produce *a* decision without disturbing Φ (the
        // operand is only a memory layout).
        let _ = s.phi_ell();
    }

    #[test]
    fn errors_leave_state_untouched() {
        let mut rng = Rng::new(11);
        let (g, _) = random_graph(&mut rng, 8, 0.4);
        let cfg = WalkConfig { n_walks: 4, max_len: 2, threads: 1, ..Default::default() };
        let mut s = StreamingFeatures::new(g, cfg, vec![1.0, 0.5, 0.25], 1);
        let before = s.phi_snapshot();
        assert!(s.apply_delta(&GraphDelta::AddEdge { u: 0, v: 99, w: 1.0 }).is_err());
        assert!(s
            .apply_delta(&GraphDelta::AddEdge { u: 0, v: 1, w: -1.0 })
            .is_err());
        // Removing a non-edge: find a non-adjacent pair.
        let mut non_edge = None;
        'outer: for u in 0..8 {
            for v in 0..8 {
                if u != v && s.graph().edge_weight(u, v) == 0.0 {
                    non_edge = Some((u, v));
                    break 'outer;
                }
            }
        }
        if let Some((u, v)) = non_edge {
            assert!(s.apply_delta(&GraphDelta::RemoveEdge { u, v }).is_err());
        }
        assert!(s.phi_snapshot() == before);
        assert_eq!(s.deltas_applied, 0);
    }

    #[test]
    fn modulation_swap_matches_fresh_build() {
        let mut rng = Rng::new(21);
        let (g, _) = random_graph(&mut rng, 12, 0.3);
        let cfg = WalkConfig { n_walks: 5, max_len: 2, threads: 1, ..Default::default() };
        let mut s = StreamingFeatures::new(g.clone(), cfg.clone(), vec![1.0, 0.5, 0.25], 3);
        s.set_compact_threshold(usize::MAX);
        s.apply_delta(&GraphDelta::AddEdge { u: 1, v: 7, w: 0.9 }).unwrap();
        let f2 = vec![0.3, 1.2, 0.8];
        s.set_modulation(f2.clone());
        let mut g2 = g;
        g2.add_edge(1, 7, 0.9);
        let full = StreamingFeatures::new(g2, cfg, f2, 3);
        assert!(s.phi_snapshot() == full.phi_snapshot());
    }
}
