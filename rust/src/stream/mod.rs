//! Streaming graph subsystem: incremental GRF maintenance for dynamic
//! graphs.
//!
//! The paper's pipeline assumes a frozen graph — a single `add_edge`
//! would force a full O(N^{3/2}) walk resample and feature rebuild.
//! But GRF walks are **node-local**: an edge delta touching (u, v)
//! only changes the transition behaviour *at* u and v, so a walk whose
//! trajectory never stepped through either endpoint replays
//! bit-identically under its own RNG stream
//! ([`crate::walks::walk_rng`]). [`StreamingFeatures`] exploits this:
//!
//! * every walk `(node, t)` is independently seeded, and the sampler
//!   emits a **visit index** `visit[j] = [(node, t), ...]` of the walks
//!   that stepped through `j` ([`crate::walks::sample_components_indexed`]);
//! * a [`GraphDelta`] invalidates exactly `visit[u] ∪ visit[v]`; only
//!   those walks are re-run, and only the rows of the affected *source*
//!   nodes are rebuilt ([`crate::walks::rows_from_walks`] — the same
//!   code path the full sampler uses, which is what makes the
//!   incremental update **bit-identical** to a from-scratch rebuild of
//!   the mutated graph under the same per-walk seeds);
//! * patched rows live in a **delta row-store** overlaying the
//!   compacted base CSRs; when the overlay exceeds its threshold the
//!   store compacts (one O(nnz) splice per matrix) and re-runs the
//!   [`crate::sparse::FeatureLayout`] selection (`to_ell_auto` policy)
//!   on the fresh Φ.
//!
//! Cost per delta: O(|visit[u]| + |visit[v]|) walk re-runs plus the
//! affected-row rebuild — independent of N for bounded-degree graphs
//! (Theorem 1 bounds the visit counts w.h.p.), against O(N · n_walks)
//! for the full resample. See `benches/hotpath.rs` (`stream_delta` vs
//! `stream_full_rebuild` rows).
//!
//! ## Batched deltas ([`StreamingFeatures::apply_delta_batch`])
//!
//! Heavy mutation traffic arrives in bursts, and per-delta application
//! wastes work three ways: overlapping invalidation sets resample the
//! same walks once per delta, each delta rebuilds its affected rows
//! even when a later delta in the burst invalidates them again, and the
//! resample loop is serial. The batch path fixes all three:
//!
//! 1. every graph mutation in the batch is applied first (cheap via the
//!    [`Graph`] per-row edge buffer), each delta's invalidation set read
//!    off the **pre-batch** visit index — sound because trajectories
//!    only change at resample time, and a walk that visited none of the
//!    batch's endpoints replays bit-identically on the final graph;
//! 2. the **union** of the per-delta sets is resampled once, partitioned
//!    by source node across [`WalkConfig`] worker threads (per-walk RNG
//!    streams make the result independent of the partition), and each
//!    affected row is rebuilt exactly once per batch;
//! 3. the overlay compaction check runs once per batch.
//!
//! The correctness anchor is unchanged: the post-batch state is
//! bit-identical to a from-scratch rebuild of the mutated graph under
//! the same per-walk seeds (property-tested below with `threads > 1`
//! and the hub cap active).
//!
//! ## Hub cap (power-law visit lists)
//!
//! A hub's exact visit list holds one `(source, walk)` entry per walk
//! that stepped through it — O(n_walks · visitors) memory on power-law
//! graphs. Each list is therefore capped at `K · n_walks` entries
//! (default `K = 32`, [`StreamingFeatures::set_hub_cap`]): an over-cap
//! node falls back to tracking only the **distinct source nodes** of
//! its visitors, and a delta touching it invalidates *all* `n_walks`
//! walks of each such source. That is a strict superset of the exact
//! set, so bit-identity is preserved (an unchanged walk re-runs to the
//! same trajectory under its own stream) while the memory drops by the
//! factor `n_walks`. Sources are added on resample but never removed
//! while saturated (another walk of the same source may still visit);
//! a stale source only widens future invalidation sets.
//!
//! **Exactification at compaction**: a saturated hub whose traffic has
//! shrunk (edges removed, walks rerouted) would otherwise stay on the
//! conservative source-level fallback forever.
//! [`StreamingFeatures::compact`] therefore re-derives each
//! small-enough saturated node's exact visit list from the per-walk
//! deposit store (the trajectories are the ground truth, and the
//! recorded source set is always a superset of the true sources) and,
//! when the exact list fits under the cap, returns the node to precise
//! invalidation — strictly smaller future resamples, features
//! untouched.
//!
//! ## Graph edge-buffer coupling
//!
//! `Graph::add_edge`/`remove_edge` stage the touched rows in the
//! graph's per-row edge buffer (O(deg) per mutation, see
//! [`crate::graph::Graph`] docs) instead of splicing the global CSR;
//! [`StreamingFeatures::compact`] folds that buffer back into canonical
//! CSR together with the feature-overlay compaction, so both caches
//! stay bounded by the same `compact_threshold` policy.
//!
//! ## Two-level overlay (stream vs model)
//!
//! This module's overlay is the **first** of two levels. The GP model
//! keeps its own: `GpModel` holds Φ/Φᵀ as
//! [`crate::sparse::RowOverlay`]s and the recombiner stages per-row
//! pattern segments, so a delta batch is O(touched nnz) end-to-end —
//! walk resample here, operand patch there, **no** O(total nnz) clone
//! or splice on either side. The model folds its overlays whenever
//! this stream reports a compaction
//! ([`BatchSummary::compacted`]), so both levels share one
//! threshold/cadence policy and the `to_ell_auto` layout re-selection
//! happens together on both fresh Φs. See the `gp::model` module docs
//! for the model half.

use crate::graph::Graph;
use crate::obs;
use crate::sparse::{Csr, Ell, FeatureLayout};
use crate::util::parallel::par_map_chunks;
use crate::walks::{
    resample_walk, rows_from_walks, NodeWalks, WalkComponents, WalkConfig,
    WalkSampler,
};
use std::collections::{BTreeMap, BTreeSet};

/// One mutation of the served graph.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphDelta {
    /// Add (or reinforce: weights sum) the undirected edge (u, v).
    AddEdge { u: usize, v: usize, w: f64 },
    /// Remove the undirected edge (u, v).
    RemoveEdge { u: usize, v: usize },
    /// Append an isolated node.
    AddNode,
}

/// What a delta actually touched — the incrementality contract.
#[derive(Clone, Debug)]
pub struct DeltaSummary {
    /// Walks that were re-run: `visit[u] ∪ visit[v]` of the pre-delta
    /// visit index (all walks of the new node for
    /// [`GraphDelta::AddNode`]). For a hub past the cap this is the
    /// source-level superset (see the module docs).
    pub resampled: Vec<(u32, u32)>,
    /// Source rows whose feature rows were rebuilt (sorted).
    pub affected_rows: Vec<u32>,
    /// Id of the appended node, for [`GraphDelta::AddNode`].
    pub added_node: Option<usize>,
    /// Whether this delta triggered an overlay compaction.
    pub compacted: bool,
}

/// Per-delta slice of a batch outcome (what the server ack reports).
#[derive(Clone, Debug)]
pub struct DeltaAck {
    /// Size of this delta's own invalidation set (before the union).
    pub invalidated: usize,
    /// Id of the appended node, for [`GraphDelta::AddNode`].
    pub added_node: Option<usize>,
}

/// Outcome of [`StreamingFeatures::apply_delta_batch`]: one union
/// resample + row rebuild shared by every delta in the batch.
#[derive(Clone, Debug)]
pub struct BatchSummary {
    /// One entry per input delta, in order.
    pub deltas: Vec<DeltaAck>,
    /// Union of the per-delta invalidation sets — the walks re-run
    /// (each exactly once, on the fully mutated graph).
    pub resampled: Vec<(u32, u32)>,
    /// Source rows rebuilt (sorted; once per batch, not per delta).
    pub affected_rows: Vec<u32>,
    /// Whether this batch triggered an overlay compaction.
    pub compacted: bool,
}

/// Per-node visit record with the hub cap applied (module docs).
#[derive(Clone, Debug)]
enum VisitList {
    /// Exact `(source, walk)` entries (unordered; removal swaps).
    Exact(Vec<(u32, u32)>),
    /// Over-cap fallback: the distinct source nodes (sorted) with at
    /// least one walk through this node. Invalidation expands to all
    /// `n_walks` walks of each source — a superset of the exact set.
    Sources(Vec<u32>),
}

impl VisitList {
    /// Record that walk `(src, t)` visits this node; saturate to
    /// source-level tracking past `cap` entries.
    fn push(&mut self, src: u32, t: u32, cap: usize) {
        match self {
            VisitList::Exact(v) => v.push((src, t)),
            VisitList::Sources(s) => {
                if let Err(k) = s.binary_search(&src) {
                    s.insert(k, src);
                }
                return;
            }
        }
        self.enforce_cap(cap);
    }

    /// Drop walk `(src, t)` from an exact list. Saturated lists keep
    /// their sources conservatively (see the module docs).
    fn remove(&mut self, src: u32, t: u32) {
        if let VisitList::Exact(v) = self {
            if let Some(p) = v.iter().position(|&e| e == (src, t)) {
                v.swap_remove(p);
            }
        }
    }

    /// Convert an over-cap exact list to source-level tracking.
    fn saturate(&mut self) {
        if let VisitList::Exact(v) = self {
            let mut s: Vec<u32> = v.iter().map(|&(src, _)| src).collect();
            s.sort_unstable();
            s.dedup();
            *self = VisitList::Sources(s);
        }
    }

    fn enforce_cap(&mut self, cap: usize) {
        if matches!(self, VisitList::Exact(v) if v.len() > cap) {
            self.saturate();
        }
    }

    /// Expand to the invalidation set: exact entries, or every walk of
    /// every recorded source when saturated.
    fn collect_into(&self, n_walks: usize, out: &mut BTreeSet<(u32, u32)>) {
        match self {
            VisitList::Exact(v) => out.extend(v.iter().copied()),
            VisitList::Sources(s) => {
                for &src in s {
                    for t in 0..n_walks as u32 {
                        out.insert((src, t));
                    }
                }
            }
        }
    }
}

/// Per-node output of one parallel resample worker, merged serially in
/// node order so the result is independent of the thread partition.
struct NodeResample {
    node: u32,
    nw: NodeWalks,
    /// Per resampled walk: (t, distinct nodes of the old trajectory,
    /// distinct nodes of the new trajectory) — the visit-index edits.
    walk_visits: Vec<(u32, Vec<u32>, Vec<u32>)>,
    patch: RowPatch,
}

/// A patched row: per-length component rows + the combined Φ row.
#[derive(Clone, Debug)]
struct RowPatch {
    per_len: Vec<(Vec<u32>, Vec<f64>)>,
    phi: (Vec<u32>, Vec<f64>),
}

/// Incrementally maintained GRF features over a mutable graph.
///
/// Holds the graph, the per-walk deposit store, the visit index, the
/// compacted base matrices (per-length components and the combined Φ
/// under a fixed modulation `f`), and the delta row-store overlay.
/// [`StreamingFeatures::apply_delta`] is the only mutation entry point;
/// the correctness anchor (property-tested below) is that the state
/// after any delta sequence is bit-identical to
/// [`StreamingFeatures::new`] on the mutated graph.
pub struct StreamingFeatures {
    graph: Graph,
    cfg: WalkConfig,
    seed: u64,
    /// `Some((shard, n_shards))` when this engine maintains only the
    /// walks whose **source** node it owns (`node % n_shards == shard`)
    /// — the per-shard worker mode of [`crate::shard::ShardedFeatures`].
    /// `None` is the classic unsharded engine owning every source.
    owner: Option<(u32, u32)>,
    /// Modulation coefficients of the maintained Φ = Σ_l f_l C_l.
    f: Vec<f64>,
    /// Current weighted degrees (empty unless `cfg.normalize`).
    norm_deg: Vec<f64>,
    store: Vec<NodeWalks>,
    visit: Vec<VisitList>,
    /// Hub cap multiplier: exact visit lists saturate past
    /// `hub_cap_k · n_walks` entries (module docs).
    hub_cap_k: usize,
    /// Compacted per-length component matrices.
    base: Vec<Csr>,
    /// Compacted combined feature matrix Φ(f).
    phi_base: Csr,
    /// Delta row-store: rows rebuilt since the last compaction.
    overlay: BTreeMap<u32, RowPatch>,
    /// Compact when the overlay holds at least this many rows.
    compact_threshold: usize,
    /// Layout policy re-run on Φ at every compaction.
    layout: FeatureLayout,
    /// ELL operand selected at the last compaction (None = CSR or
    /// policy rejection); stale while the overlay is non-empty.
    phi_ell: Option<Ell>,
    /// Lifetime counters (observability for the server stats op).
    pub deltas_applied: usize,
    pub walks_resampled_total: usize,
    pub compactions: usize,
}

/// Combine per-length rows into the Φ row: gather `(col, f_l · v)` in
/// length order, sort by column, merge runs. Shared by the full build
/// and the patcher so both produce bitwise-equal rows. Zero
/// coefficients still contribute pattern entries (the row pattern is
/// the union pattern, as in [`crate::walks::CombinedFeatures`]).
fn combine_row(per_len: &[(Vec<u32>, Vec<f64>)], f: &[f64]) -> (Vec<u32>, Vec<f64>) {
    debug_assert_eq!(per_len.len(), f.len());
    let mut ent: Vec<(u32, f64)> = Vec::new();
    for ((cols, vals), &fl) in per_len.iter().zip(f) {
        for (c, v) in cols.iter().zip(vals) {
            ent.push((*c, fl * v));
        }
    }
    ent.sort_unstable_by_key(|&(c, _)| c);
    let mut cols = Vec::with_capacity(ent.len());
    let mut vals = Vec::with_capacity(ent.len());
    let mut k = 0;
    while k < ent.len() {
        let c = ent[k].0;
        let mut v = 0.0;
        while k < ent.len() && ent[k].0 == c {
            v += ent[k].1;
            k += 1;
        }
        cols.push(c);
        vals.push(v);
    }
    (cols, vals)
}

/// Assemble Φ = Σ_l f_l C_l row-by-row through [`combine_row`] — the
/// single constructor shared by the fresh build and the modulation
/// swap (the bit-identity between those paths depends on it).
fn build_phi(base: &[Csr], n_cols: usize, f: &[f64]) -> Csr {
    let n = base.first().map(|c| c.n_rows).unwrap_or(0);
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    let mut scratch: Vec<(Vec<u32>, Vec<f64>)> = Vec::with_capacity(f.len());
    for r in 0..n {
        scratch.clear();
        for c in base {
            let (rc, rv) = c.row(r);
            scratch.push((rc.to_vec(), rv.to_vec()));
        }
        let (pc, pv) = combine_row(&scratch, f);
        cols.extend_from_slice(&pc);
        vals.extend_from_slice(&pv);
        offsets.push(cols.len());
    }
    Csr { n_rows: n, n_cols, offsets, cols, vals }
}

impl StreamingFeatures {
    /// Full (parallel) build on a static graph — also the reference the
    /// incremental path is tested against.
    pub fn new(graph: Graph, cfg: WalkConfig, f: Vec<f64>, seed: u64) -> StreamingFeatures {
        StreamingFeatures::new_owned(graph, cfg, f, seed, None)
    }

    /// Partition-filtered build: with `owner = Some((shard, n_shards))`
    /// this engine samples, indexes, and maintains **only** the walks
    /// whose source it owns; foreign sources keep empty stores, empty
    /// feature rows, and empty visit lists. Per-walk RNG streams make
    /// the owned rows bitwise the corresponding rows of the unsharded
    /// engine — see [`crate::shard::ShardedFeatures`], which composes a
    /// full engine out of `n_shards` of these.
    pub fn new_owned(
        graph: Graph,
        cfg: WalkConfig,
        f: Vec<f64>,
        seed: u64,
        owner: Option<(u32, u32)>,
    ) -> StreamingFeatures {
        assert_eq!(f.len(), cfg.max_len + 1, "modulation length != l_max+1");
        if let Some((shard, count)) = owner {
            assert!(count > 0 && shard < count, "owner {shard} out of {count}");
        }
        let n = graph.num_nodes();
        let sampler = WalkSampler::new(&graph, &cfg, seed);
        let iw = match owner {
            Some((shard, count)) => sampler.partition(shard, count),
            None => sampler.indexed(),
        };
        let norm_deg: Vec<f64> = if cfg.normalize {
            (0..n).map(|i| graph.weighted_degree(i).max(1e-12)).collect()
        } else {
            Vec::new()
        };
        let base = iw.components.c;
        let phi_base = build_phi(&base, n, &f);
        let layout = FeatureLayout::Auto;
        let phi_ell = phi_base.select_ell(layout);
        let hub_cap_k = 32;
        let cap = hub_cap_k * cfg.n_walks;
        let visit = iw
            .visit
            .into_iter()
            .map(|v| {
                let mut vl = VisitList::Exact(v);
                vl.enforce_cap(cap);
                vl
            })
            .collect();
        StreamingFeatures {
            graph,
            cfg,
            seed,
            owner,
            f,
            norm_deg,
            store: iw.store,
            visit,
            hub_cap_k,
            base,
            phi_base,
            overlay: BTreeMap::new(),
            compact_threshold: (n / 8).max(64),
            layout,
            phi_ell,
            deltas_applied: 0,
            walks_resampled_total: 0,
            compactions: 0,
        }
    }

    pub fn n(&self) -> usize {
        self.graph.num_nodes()
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn config(&self) -> &WalkConfig {
        &self.cfg
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Does this engine own (sample and maintain) walks sourced at
    /// `node`? Always true for the unsharded engine.
    pub fn owns(&self, node: usize) -> bool {
        match self.owner {
            Some((shard, count)) => node as u32 % count == shard,
            None => true,
        }
    }

    /// The `(shard, n_shards)` partition slot, if this is a per-shard
    /// worker engine.
    pub fn owner(&self) -> Option<(u32, u32)> {
        self.owner
    }

    pub fn modulation(&self) -> &[f64] {
        &self.f
    }

    /// Rows currently held in the delta row-store.
    pub fn overlay_rows(&self) -> usize {
        self.overlay.len()
    }

    /// Overlay size that triggers compaction (default `max(64, n/8)`).
    pub fn set_compact_threshold(&mut self, rows: usize) {
        self.compact_threshold = rows.max(1);
    }

    /// Set the hub-cap multiplier `K`: a node's exact visit list
    /// saturates to source-level tracking past `K · n_walks` entries
    /// (default 32; see the module docs for the fallback rule).
    /// Lowering it saturates existing over-cap lists immediately.
    pub fn set_hub_cap(&mut self, k: usize) {
        self.hub_cap_k = k.max(1);
        let cap = self.hub_cap_k * self.cfg.n_walks;
        for vl in &mut self.visit {
            vl.enforce_cap(cap);
        }
    }

    /// Nodes whose visit lists run in the saturated (source-level)
    /// fallback — observability for the server stats op.
    pub fn saturated_hubs(&self) -> usize {
        self.visit
            .iter()
            .filter(|v| matches!(v, VisitList::Sources(_)))
            .count()
    }

    /// The layout policy re-run on Φ at each compaction.
    pub fn set_layout(&mut self, layout: FeatureLayout) {
        self.layout = layout;
        self.phi_ell = self.phi_base.select_ell(layout);
    }

    /// ELL operand of the compacted Φ (as of the last compaction;
    /// `None` when the policy kept CSR or the overlay pre-empts it).
    pub fn phi_ell(&self) -> Option<&Ell> {
        if self.overlay.is_empty() {
            self.phi_ell.as_ref()
        } else {
            None
        }
    }

    /// All walks whose trajectories stepped through any of `nodes` —
    /// the invalidation set of a delta touching those endpoints. For a
    /// saturated hub this expands to every walk of each recorded
    /// source (a superset; see the module docs).
    pub fn visiting_walks(&self, nodes: &[usize]) -> BTreeSet<(u32, u32)> {
        let mut out = BTreeSet::new();
        for &i in nodes {
            if i < self.visit.len() {
                self.visit[i].collect_into(self.cfg.n_walks, &mut out);
            }
        }
        out
    }

    /// Current content of component row `r` at length `l` (overlay wins
    /// over base; rows beyond the base are empty until patched).
    pub fn component_row(&self, l: usize, r: usize) -> (Vec<u32>, Vec<f64>) {
        if let Some(p) = self.overlay.get(&(r as u32)) {
            p.per_len[l].clone()
        } else if r < self.base[l].n_rows {
            let (c, v) = self.base[l].row(r);
            (c.to_vec(), v.to_vec())
        } else {
            (Vec::new(), Vec::new())
        }
    }

    /// Materialise the current per-length components (base + overlay).
    pub fn components(&self) -> WalkComponents {
        let n = self.n();
        let c = (0..self.base.len())
            .map(|l| {
                let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
                    .overlay
                    .iter()
                    .map(|(&r, p)| (r, p.per_len[l].clone()))
                    .collect();
                self.base[l].with_replaced_rows(n, n, &patches)
            })
            .collect();
        WalkComponents::new(c)
    }

    /// Materialise the current Φ (base + overlay).
    pub fn phi_snapshot(&self) -> Csr {
        let n = self.n();
        if self.overlay.is_empty() && self.phi_base.n_rows == n {
            return self.phi_base.clone();
        }
        let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
            .overlay
            .iter()
            .map(|(&r, p)| (r, p.phi.clone()))
            .collect();
        self.phi_base.with_replaced_rows(n, n, &patches)
    }

    /// Swap the modulation and recombine every Φ row (components are
    /// untouched — walks don't depend on `f`). O(nnz).
    pub fn set_modulation(&mut self, f: Vec<f64>) {
        assert_eq!(f.len(), self.cfg.max_len + 1);
        self.f = f;
        // Rebuild phi_base from the base components, then the overlay
        // Φ rows from their per-length patches. The column count is the
        // *current* node count, not `phi_base.n_cols` — after a
        // pre-compaction AddNode the latter is stale (the appended row
        // lives only in the overlay until the next compaction).
        self.phi_base = build_phi(&self.base, self.n(), &self.f);
        let f = self.f.clone();
        for p in self.overlay.values_mut() {
            p.phi = combine_row(&p.per_len, &f);
        }
        self.phi_ell = self.phi_base.select_ell(self.layout);
    }

    /// Apply one graph mutation: resample exactly the invalidated
    /// walks, rebuild the affected rows into the overlay, maybe
    /// compact. Errors leave the state untouched. A single-delta batch
    /// through the shared engine ([`StreamingFeatures::apply_delta_batch`]).
    pub fn apply_delta(&mut self, delta: &GraphDelta) -> Result<DeltaSummary, String> {
        let batch = self.apply_delta_batch(std::slice::from_ref(delta))?;
        Ok(DeltaSummary {
            resampled: batch.resampled,
            affected_rows: batch.affected_rows,
            added_node: batch.deltas[0].added_node,
            compacted: batch.compacted,
        })
    }

    /// Apply a batch of graph mutations with one union invalidation,
    /// one parallel resample, and one row rebuild per affected node
    /// (module docs). The whole batch is validated up front against a
    /// simulated edge overlay, so errors leave the state untouched.
    pub fn apply_delta_batch(
        &mut self,
        deltas: &[GraphDelta],
    ) -> Result<BatchSummary, String> {
        if deltas.is_empty() {
            return Ok(BatchSummary {
                deltas: Vec::new(),
                resampled: Vec::new(),
                affected_rows: Vec::new(),
                compacted: false,
            });
        }
        self.validate_batch(deltas)?;
        // Phase 1: apply every graph mutation, reading each delta's
        // invalidation set off the pre-batch visit index (trajectories
        // only change at resample time, so the index is stable across
        // the whole mutation phase; only AddNode appends empty lists).
        let mut union: BTreeSet<(u32, u32)> = BTreeSet::new();
        let mut acks = Vec::with_capacity(deltas.len());
        let mut touched: BTreeSet<usize> = BTreeSet::new();
        for delta in deltas {
            let (inv, added_node) = match *delta {
                GraphDelta::AddEdge { u, v, w } => {
                    let inv = self.visiting_walks(&[u, v]);
                    self.graph.add_edge(u, v, w);
                    touched.insert(u);
                    touched.insert(v);
                    (inv, None)
                }
                GraphDelta::RemoveEdge { u, v } => {
                    let inv = self.visiting_walks(&[u, v]);
                    let removed = self.graph.remove_edge(u, v);
                    debug_assert!(removed, "validated above");
                    touched.insert(u);
                    touched.insert(v);
                    (inv, None)
                }
                GraphDelta::AddNode => {
                    let id = self.graph.add_node();
                    self.visit.push(VisitList::Exact(Vec::new()));
                    self.store.push(NodeWalks {
                        offsets: vec![0],
                        deposits: Vec::new(),
                    });
                    if self.cfg.normalize {
                        self.norm_deg.push(0.0);
                        touched.insert(id);
                    }
                    // The appended node's walks belong to its owner
                    // shard; a foreign shard only grows its index.
                    let inv: BTreeSet<(u32, u32)> = if self.owns(id) {
                        (0..self.cfg.n_walks)
                            .map(|t| (id as u32, t as u32))
                            .collect()
                    } else {
                        BTreeSet::new()
                    };
                    (inv, Some(id))
                }
            };
            acks.push(DeltaAck { invalidated: inv.len(), added_node });
            union.extend(inv);
        }
        // Weighted degrees refresh once, after all mutations — exactly
        // the values a from-scratch build on the final graph would see.
        if self.cfg.normalize {
            for &i in &touched {
                self.norm_deg[i] = self.graph.weighted_degree(i).max(1e-12);
            }
        }
        // Phase 2: one parallel resample of the union + row rebuild.
        obs::registry::STREAM_DELTA_BATCHES.inc();
        obs::registry::RESAMPLE_WALKS.record(union.len() as u64);
        let resample_span = obs::span::Span::new(&obs::registry::RESAMPLE_NS);
        let (resampled, affected_rows) = self.resample_invalidated(&union);
        resample_span.stop();
        obs::registry::RESAMPLE_ROWS.record(affected_rows.len() as u64);
        self.deltas_applied += deltas.len();
        self.walks_resampled_total += resampled.len();
        let mut compacted = false;
        if self.overlay.len() >= self.compact_threshold {
            let _s = obs::span::Span::new(&obs::registry::COMPACT_NS);
            self.compact();
            compacted = true;
        }
        Ok(BatchSummary {
            deltas: acks,
            resampled,
            affected_rows,
            compacted,
        })
    }

    /// Pre-validate a delta batch against a simulated node count and
    /// edge overlay — no state is touched, so a failing batch is a
    /// clean no-op.
    fn validate_batch(&self, deltas: &[GraphDelta]) -> Result<(), String> {
        let mut n_sim = self.n();
        let mut edge_sim: BTreeMap<(usize, usize), bool> = BTreeMap::new();
        for (k, delta) in deltas.iter().enumerate() {
            match *delta {
                GraphDelta::AddEdge { u, v, w } => {
                    if u >= n_sim || v >= n_sim {
                        return Err(format!(
                            "delta {k}: add_edge ({u},{v}) out of range (n={n_sim})"
                        ));
                    }
                    if !w.is_finite() || w <= 0.0 {
                        return Err(format!(
                            "delta {k}: add_edge weight {w} must be finite and > 0"
                        ));
                    }
                    edge_sim.insert((u.min(v), u.max(v)), true);
                }
                GraphDelta::RemoveEdge { u, v } => {
                    if u >= n_sim || v >= n_sim {
                        return Err(format!(
                            "delta {k}: remove_edge ({u},{v}) out of range (n={n_sim})"
                        ));
                    }
                    let key = (u.min(v), u.max(v));
                    let present = edge_sim.get(&key).copied().unwrap_or_else(|| {
                        u < self.n() && v < self.n() && self.graph.has_edge(u, v)
                    });
                    if !present {
                        return Err(format!(
                            "delta {k}: remove_edge ({u},{v}): no such edge"
                        ));
                    }
                    edge_sim.insert(key, false);
                }
                GraphDelta::AddNode => n_sim += 1,
            }
        }
        Ok(())
    }

    /// Merge the overlay into the base matrices, fold the graph's
    /// staged per-row edge buffer back into canonical CSR, re-run
    /// the `to_ell_auto` layout policy on the fresh Φ, and exactify
    /// saturated hubs whose traffic has shrunk under the cap
    /// (module docs).
    pub fn compact(&mut self) {
        let n = self.n();
        self.graph.compact();
        self.exactify_hubs();
        for l in 0..self.base.len() {
            let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
                .overlay
                .iter()
                .map(|(&r, p)| (r, p.per_len[l].clone()))
                .collect();
            self.base[l] = self.base[l].with_replaced_rows(n, n, &patches);
        }
        let patches: BTreeMap<u32, (Vec<u32>, Vec<f64>)> = self
            .overlay
            .iter()
            .map(|(&r, p)| (r, p.phi.clone()))
            .collect();
        self.phi_base = self.phi_base.with_replaced_rows(n, n, &patches);
        self.overlay.clear();
        self.phi_ell = self.phi_base.select_ell(self.layout);
        self.compactions += 1;
        obs::registry::STREAM_COMPACTIONS.inc();
    }

    /// Return saturated hubs to precise invalidation where possible:
    /// for each [`VisitList::Sources`] node with a small enough source
    /// set, replay the recorded sources' trajectories out of the
    /// deposit store to recover the **exact** `(source, walk)` visitor
    /// list, and install it when it fits under the cap. The recorded
    /// source set is always a superset of the true sources (sources
    /// are only ever added while saturated), so the re-derived list is
    /// exactly what a from-scratch build's visit index would hold —
    /// future deltas at the node resample a (weak) subset of what the
    /// source-level fallback would have, with bit-identical features.
    fn exactify_hubs(&mut self) {
        let cap = self.hub_cap_k * self.cfg.n_walks;
        for j in 0..self.visit.len() {
            let sources = match &self.visit[j] {
                // Work bound, not a correctness gate: the replay below
                // costs O(|s| · n_walks · walk_len), so only attempt
                // hubs whose recorded source set has shrunk to roughly
                // cap scale (a still-hot hub with thousands of live
                // sources would fail the exact-size check anyway).
                VisitList::Sources(s) if s.len() <= cap => s.clone(),
                _ => continue,
            };
            let mut exact: Vec<(u32, u32)> = Vec::new();
            'derive: for &src in &sources {
                let nw = &self.store[src as usize];
                for t in 0..nw.n_walks() {
                    if nw.walk(t).iter().any(|&(node, _)| node as usize == j) {
                        exact.push((src, t as u32));
                        if exact.len() > cap {
                            break 'derive;
                        }
                    }
                }
            }
            if exact.len() <= cap {
                self.visit[j] = VisitList::Exact(exact);
            }
        }
    }

    /// Re-run the given walks on the current graph **in parallel**
    /// (partitioned by source node across the configured worker
    /// threads), rebuild each affected row once, and stage the patches
    /// in the overlay. Per-walk RNG streams make every worker output a
    /// pure function of (graph, seed, walk id), and the visit-index /
    /// overlay merge runs serially in node order — so the result is
    /// bit-identical across thread counts and to the old serial path.
    fn resample_invalidated(
        &mut self,
        invalid: &BTreeSet<(u32, u32)>,
    ) -> (Vec<(u32, u32)>, Vec<u32>) {
        let n_len = self.cfg.max_len + 1;
        let inv_n = 1.0 / self.cfg.n_walks as f64;
        let mut by_node: BTreeMap<u32, BTreeSet<u32>> = BTreeMap::new();
        for &(i, t) in invalid {
            by_node.entry(i).or_default().insert(t);
        }
        // Take the old per-walk stores out so the workers own them.
        let jobs: Vec<(u32, BTreeSet<u32>, NodeWalks)> = by_node
            .into_iter()
            .map(|(i, ts)| {
                let old = std::mem::take(&mut self.store[i as usize]);
                (i, ts, old)
            })
            .collect();
        let threads = self.cfg.effective_threads().min(jobs.len().max(1));
        let graph = &self.graph;
        let cfg = &self.cfg;
        let norm_deg = &self.norm_deg;
        let seed = self.seed;
        let f = &self.f;
        let results: Vec<Vec<NodeResample>> =
            par_map_chunks(jobs.len(), threads, |s, e, _| {
                let mut out = Vec::with_capacity(e - s);
                let mut seen: Vec<u32> = Vec::new();
                for (i, ts, old) in &jobs[s..e] {
                    let iu = *i as usize;
                    let mut nw = NodeWalks {
                        offsets: Vec::with_capacity(cfg.n_walks + 1),
                        deposits: Vec::new(),
                    };
                    nw.offsets.push(0);
                    let mut walk_visits = Vec::with_capacity(ts.len());
                    for t in 0..cfg.n_walks {
                        let start = nw.deposits.len();
                        if ts.contains(&(t as u32)) {
                            // Distinct nodes of the old trajectory (its
                            // visit entries to drop)...
                            let old_nodes = if t < old.n_walks() {
                                seen.clear();
                                seen.extend(
                                    old.walk(t).iter().map(|&(j, _)| j),
                                );
                                seen.sort_unstable();
                                seen.dedup();
                                seen.clone()
                            } else {
                                Vec::new()
                            };
                            // ...re-run under its own stream...
                            resample_walk(
                                graph, cfg, norm_deg, iu, t, seed,
                                &mut nw.deposits,
                            );
                            // ...and the new trajectory to index.
                            seen.clear();
                            seen.extend(
                                nw.deposits[start..].iter().map(|&(j, _)| j),
                            );
                            seen.sort_unstable();
                            seen.dedup();
                            walk_visits.push((t as u32, old_nodes, seen.clone()));
                        } else {
                            nw.deposits.extend_from_slice(old.walk(t));
                        }
                        nw.offsets.push(nw.deposits.len() as u32);
                    }
                    let per_len = rows_from_walks(&nw, n_len, inv_n);
                    let phi = combine_row(&per_len, f);
                    out.push(NodeResample {
                        node: *i,
                        nw,
                        walk_visits,
                        patch: RowPatch { per_len, phi },
                    });
                }
                out
            });
        // Serial merge in node order: visit-index edits + overlay
        // staging (identical edit sequence to the old serial loop).
        let cap = self.hub_cap_k * self.cfg.n_walks;
        let mut affected_rows = Vec::new();
        for nr in results.into_iter().flatten() {
            let i = nr.node;
            for (t, old_nodes, new_nodes) in &nr.walk_visits {
                for &j in old_nodes {
                    self.visit[j as usize].remove(i, *t);
                }
                for &j in new_nodes {
                    self.visit[j as usize].push(i, *t, cap);
                }
            }
            self.store[i as usize] = nr.nw;
            self.overlay.insert(i, nr.patch);
            affected_rows.push(i);
        }
        (invalid.iter().copied().collect(), affected_rows)
    }
}

/// What the GP model needs from a feature-maintenance engine to run
/// its delta path — implemented by the unsharded
/// [`StreamingFeatures`], the partitioned
/// [`crate::shard::ShardedFeatures`], and the server's
/// [`crate::shard::FeatureEngine`] dispatcher. The contract every
/// implementor must honour: after `apply_delta_batch`, `component_row`
/// returns rows **bitwise identical** to a from-scratch build on the
/// mutated graph under the same per-walk seeds.
pub trait DeltaEngine {
    /// Current node count.
    fn n(&self) -> usize;
    /// The walk configuration the features are sampled under.
    fn walk_config(&self) -> &WalkConfig;
    /// Apply a validated batch of graph mutations; errors must leave
    /// the engine untouched.
    fn apply_delta_batch(&mut self, deltas: &[GraphDelta]) -> Result<BatchSummary, String>;
    /// Current content of component row `r` at length `l`.
    fn component_row(&self, l: usize, r: usize) -> (Vec<u32>, Vec<f64>);
}

impl DeltaEngine for StreamingFeatures {
    fn n(&self) -> usize {
        StreamingFeatures::n(self)
    }

    fn walk_config(&self) -> &WalkConfig {
        self.config()
    }

    fn apply_delta_batch(&mut self, deltas: &[GraphDelta]) -> Result<BatchSummary, String> {
        StreamingFeatures::apply_delta_batch(self, deltas)
    }

    fn component_row(&self, l: usize, r: usize) -> (Vec<u32>, Vec<f64>) {
        StreamingFeatures::component_row(self, l, r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::proptest;
    use crate::util::rng::Rng;

    fn random_graph(rng: &mut Rng, n: usize, p: f64) -> (Graph, Vec<(u32, u32, f64)>) {
        let mut edges = Vec::new();
        for i in 0..n as u32 {
            for j in (i + 1)..n as u32 {
                if rng.bernoulli(p) {
                    edges.push((i, j, 0.2 + 0.8 * rng.uniform()));
                }
            }
        }
        (Graph::from_edges(n, &edges), edges)
    }

    /// Random walk config; the termination scheme is drawn from the
    /// [`Termination::test_matrix`] env knob (`GRFGP_TEST_TERMINATION`,
    /// default: every scheme), so the bitwise properties below cover
    /// the whole scheme matrix across proptest cases.
    fn test_cfg(rng: &mut Rng) -> WalkConfig {
        let schemes = crate::walks::Termination::test_matrix();
        WalkConfig {
            n_walks: 6 + rng.below(6),
            p_halt: 0.15,
            max_len: 3,
            reweight: true,
            normalize: rng.bernoulli(0.5),
            termination: schemes[rng.below(schemes.len())],
            threads: 1,
        }
    }

    fn random_delta(g: &Graph, rng: &mut Rng) -> GraphDelta {
        let n = g.num_nodes();
        match rng.below(4) {
            0 => GraphDelta::AddNode,
            1 => {
                // Remove a random existing edge if any.
                let with_deg: Vec<usize> =
                    (0..n).filter(|&i| g.degree(i) > 0).collect();
                if with_deg.is_empty() {
                    GraphDelta::AddNode
                } else {
                    let u = with_deg[rng.below(with_deg.len())];
                    let v = g.neighbors(u)[rng.below(g.degree(u))] as usize;
                    GraphDelta::RemoveEdge { u, v }
                }
            }
            _ => {
                let u = rng.below(n);
                let v = rng.below(n);
                GraphDelta::AddEdge { u, v, w: 0.2 + 0.8 * rng.uniform() }
            }
        }
    }

    /// Acceptance property: for random graphs, random deltas, and fixed
    /// seeds, the incremental state is bit-identical to a from-scratch
    /// rebuild of the mutated graph, and only walks that visited the
    /// delta endpoints were resampled.
    #[test]
    fn incremental_matches_full_rebuild_bitwise() {
        proptest(8, |rng| {
            let n = 8 + rng.below(10);
            let (g, _) = random_graph(rng, n, 0.25);
            let cfg = test_cfg(rng);
            let f = vec![1.0, 0.6, 0.3, 0.1];
            let seed = rng.next_u64();
            let mut s =
                StreamingFeatures::new(g.clone(), cfg.clone(), f.clone(), seed);
            // Exercise both the overlay path and per-delta compaction.
            let threshold = if rng.bernoulli(0.5) { 1 } else { usize::MAX };
            s.set_compact_threshold(threshold);
            let mut g2 = g;
            for step in 0..5 {
                let delta = random_delta(&g2, rng);
                // Expected invalidation set from the PRE-delta index.
                let expect: BTreeSet<(u32, u32)> = match delta {
                    GraphDelta::AddEdge { u, v, .. }
                    | GraphDelta::RemoveEdge { u, v } => {
                        s.visiting_walks(&[u, v])
                    }
                    GraphDelta::AddNode => (0..cfg.n_walks)
                        .map(|t| (g2.num_nodes() as u32, t as u32))
                        .collect(),
                };
                // Mirror the delta on the reference graph.
                match delta {
                    GraphDelta::AddEdge { u, v, w } => g2.add_edge(u, v, w),
                    GraphDelta::RemoveEdge { u, v } => {
                        g2.remove_edge(u, v);
                    }
                    GraphDelta::AddNode => {
                        g2.add_node();
                    }
                }
                let sum = s.apply_delta(&delta).unwrap();
                let got: BTreeSet<(u32, u32)> =
                    sum.resampled.iter().copied().collect();
                prop_assert!(
                    got == expect,
                    "step {step}: resampled {got:?} != visit-index set {expect:?}"
                );
                let full = StreamingFeatures::new(
                    g2.clone(),
                    cfg.clone(),
                    f.clone(),
                    seed,
                );
                prop_assert!(
                    s.phi_snapshot() == full.phi_snapshot(),
                    "step {step} ({delta:?}): Φ not bit-identical to rebuild"
                );
                let (a, b) = (s.components().c, full.components().c);
                for l in 0..a.len() {
                    prop_assert!(
                        a[l] == b[l],
                        "step {step}: component {l} not bit-identical"
                    );
                }
            }
            Ok(())
        });
    }

    /// Acceptance property (batch engine): random batches of deltas,
    /// worker threads > 1, hub cap active — the batched state is
    /// bit-identical to a from-scratch rebuild of the mutated graph,
    /// and to the same deltas applied one at a time.
    #[test]
    fn batched_deltas_match_rebuild_and_sequential_bitwise() {
        proptest(6, |rng| {
            let n = 8 + rng.below(10);
            let (g, _) = random_graph(rng, n, 0.3);
            let schemes = crate::walks::Termination::test_matrix();
            let cfg = WalkConfig {
                n_walks: 6 + rng.below(4),
                p_halt: 0.15,
                max_len: 3,
                reweight: true,
                normalize: rng.bernoulli(0.5),
                termination: schemes[rng.below(schemes.len())],
                threads: 2 + rng.below(3),
            };
            let f = vec![1.0, 0.6, 0.3, 0.1];
            let seed = rng.next_u64();
            let mut batched =
                StreamingFeatures::new(g.clone(), cfg.clone(), f.clone(), seed);
            let mut serial =
                StreamingFeatures::new(g.clone(), cfg.clone(), f.clone(), seed);
            // Saturate hub visit lists immediately so the source-level
            // fallback is exercised, and flip compaction on one side.
            batched.set_hub_cap(1);
            serial.set_hub_cap(1);
            batched.set_compact_threshold(if rng.bernoulli(0.5) {
                1
            } else {
                usize::MAX
            });
            serial.set_compact_threshold(usize::MAX);
            let mut g2 = g;
            for round in 0..3 {
                let k = 1 + rng.below(5);
                let mut deltas = Vec::with_capacity(k);
                for _ in 0..k {
                    // Draw against the evolving reference graph so
                    // RemoveEdge targets stay valid within the batch.
                    let d = random_delta(&g2, rng);
                    match d {
                        GraphDelta::AddEdge { u, v, w } => g2.add_edge(u, v, w),
                        GraphDelta::RemoveEdge { u, v } => {
                            g2.remove_edge(u, v);
                        }
                        GraphDelta::AddNode => {
                            g2.add_node();
                        }
                    }
                    deltas.push(d);
                }
                let out = batched.apply_delta_batch(&deltas).unwrap();
                prop_assert!(
                    out.deltas.len() == deltas.len(),
                    "round {round}: one ack per delta"
                );
                for d in &deltas {
                    serial.apply_delta(d).unwrap();
                }
                let full = StreamingFeatures::new(
                    g2.clone(),
                    cfg.clone(),
                    f.clone(),
                    seed,
                );
                prop_assert!(
                    batched.phi_snapshot() == full.phi_snapshot(),
                    "round {round}: batched Φ != rebuild"
                );
                prop_assert!(
                    batched.phi_snapshot() == serial.phi_snapshot(),
                    "round {round}: batched Φ != sequential"
                );
                let (a, b) = (batched.components().c, full.components().c);
                for l in 0..a.len() {
                    prop_assert!(
                        a[l] == b[l],
                        "round {round}: component {l} != rebuild"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_validation_errors_leave_state_untouched() {
        let mut rng = Rng::new(15);
        let (g, _) = random_graph(&mut rng, 10, 0.4);
        let cfg = WalkConfig { n_walks: 4, max_len: 2, threads: 2, ..Default::default() };
        let mut s = StreamingFeatures::new(g, cfg, vec![1.0, 0.5, 0.25], 2);
        let before = s.phi_snapshot();
        let g0 = s.graph().clone();
        // Second delta removes an edge the batch never added and the
        // graph does not have: the whole batch must be a no-op.
        let mut non_edge = None;
        'outer: for u in 0..10 {
            for v in 0..10 {
                if u != v && !s.graph().has_edge(u, v) {
                    non_edge = Some((u, v));
                    break 'outer;
                }
            }
        }
        let (u, v) = non_edge.expect("sparse graph has a non-edge");
        let bad = vec![
            GraphDelta::AddEdge { u: 0, v: 1, w: 0.5 },
            GraphDelta::RemoveEdge { u, v },
        ];
        assert!(s.apply_delta_batch(&bad).is_err());
        assert!(s.phi_snapshot() == before);
        assert_eq!(s.deltas_applied, 0);
        assert_eq!(s.graph().num_edges(), g0.num_edges());
        // A remove of an edge added earlier in the same batch is valid.
        let good = vec![
            GraphDelta::AddEdge { u, v, w: 0.5 },
            GraphDelta::RemoveEdge { u, v },
        ];
        let out = s.apply_delta_batch(&good).unwrap();
        assert_eq!(out.deltas.len(), 2);
        assert!(s.phi_snapshot() == before, "add+remove roundtrip in one batch");
    }

    #[test]
    fn self_loop_deltas_match_rebuild_bitwise() {
        // add_edge(u,u) / remove_edge(u,u) through the streaming path:
        // the walk transition treats the loop as one directed entry and
        // num_edges counts it once — both defined on the static path,
        // here exercised through mutations.
        let edges: Vec<(u32, u32, f64)> =
            (0..11).map(|i| (i, i + 1, 1.0)).collect();
        let g = Graph::from_edges(12, &edges);
        let cfg = WalkConfig { n_walks: 8, max_len: 3, threads: 2, ..Default::default() };
        let f = vec![1.0, 0.5, 0.25, 0.125];
        let mut s = StreamingFeatures::new(g.clone(), cfg.clone(), f.clone(), 23);
        let before = s.phi_snapshot();
        let e0 = s.graph().num_edges();
        let sum = s
            .apply_delta(&GraphDelta::AddEdge { u: 3, v: 3, w: 0.9 })
            .unwrap();
        assert!(!sum.resampled.is_empty(), "walks visit node 3");
        assert_eq!(s.graph().num_edges(), e0 + 1, "self-loop counts once");
        assert_eq!(s.graph().degree(3), 3, "single directed entry");
        let mut g2 = g.clone();
        g2.add_edge(3, 3, 0.9);
        let full = StreamingFeatures::new(g2, cfg.clone(), f.clone(), 23);
        assert!(
            s.phi_snapshot() == full.phi_snapshot(),
            "self-loop add not bit-identical to rebuild"
        );
        s.apply_delta(&GraphDelta::RemoveEdge { u: 3, v: 3 }).unwrap();
        assert_eq!(s.graph().num_edges(), e0);
        assert!(
            s.phi_snapshot() == before,
            "self-loop roundtrip must restore Φ bitwise"
        );
        // And through the batch path, mixed with a normal edge.
        let out = s
            .apply_delta_batch(&[
                GraphDelta::AddEdge { u: 5, v: 5, w: 0.4 },
                GraphDelta::AddEdge { u: 0, v: 7, w: 0.6 },
                GraphDelta::RemoveEdge { u: 5, v: 5 },
            ])
            .unwrap();
        assert_eq!(out.deltas.len(), 3);
        let mut g3 = g;
        g3.add_edge(0, 7, 0.6);
        let full3 = StreamingFeatures::new(g3, cfg, f, 23);
        assert!(s.phi_snapshot() == full3.phi_snapshot());
    }

    #[test]
    fn modulation_swap_after_pre_compaction_add_node() {
        // Regression: set_modulation used the stale phi_base.n_cols to
        // rebuild Φ after a pre-compaction AddNode. The swapped state
        // must stay bitwise equal to a fresh build of the mutated graph
        // under the new modulation, before and after compaction.
        let mut rng = Rng::new(31);
        let (g, _) = random_graph(&mut rng, 10, 0.3);
        let cfg = WalkConfig { n_walks: 5, max_len: 2, threads: 1, ..Default::default() };
        let mut s =
            StreamingFeatures::new(g.clone(), cfg.clone(), vec![1.0, 0.5, 0.25], 13);
        s.set_compact_threshold(usize::MAX);
        s.apply_delta(&GraphDelta::AddNode).unwrap();
        assert!(s.overlay_rows() > 0, "AddNode row must be pre-compaction");
        let f2 = vec![0.4, 1.1, 0.7];
        s.set_modulation(f2.clone());
        let mut g2 = g;
        g2.add_node();
        let full = StreamingFeatures::new(g2, cfg, f2, 13);
        let snap = s.phi_snapshot();
        assert_eq!(snap.n_rows, 11);
        assert_eq!(snap.n_cols, 11);
        assert!(snap == full.phi_snapshot(), "swap after AddNode diverged");
        s.compact();
        assert!(
            s.phi_snapshot() == full.phi_snapshot(),
            "compaction after the swap diverged"
        );
    }

    #[test]
    fn hub_cap_saturates_and_stays_bit_identical() {
        // A star graph makes the centre a hub visited by every spoke
        // walk; with K = 1 the centre's list saturates to source-level
        // tracking, invalidation becomes the all-walks superset, and
        // deltas must stay bit-identical to a rebuild.
        let edges: Vec<(u32, u32, f64)> =
            (1..16).map(|i| (0, i, 1.0)).collect();
        let g = Graph::from_edges(16, &edges);
        let cfg = WalkConfig { n_walks: 6, max_len: 3, threads: 2, ..Default::default() };
        let f = vec![1.0, 0.5, 0.25, 0.125];
        let mut s = StreamingFeatures::new(g.clone(), cfg.clone(), f.clone(), 3);
        s.set_hub_cap(1);
        assert!(s.saturated_hubs() > 0, "star centre must saturate at K=1");
        // Invalidation at the centre covers whole sources: every
        // (src, t) of a listed source appears.
        let inv = s.visiting_walks(&[0]);
        let sources: BTreeSet<u32> = inv.iter().map(|&(i, _)| i).collect();
        for &src in &sources {
            for t in 0..cfg.n_walks as u32 {
                assert!(inv.contains(&(src, t)), "src {src} walk {t} missing");
            }
        }
        let sum = s
            .apply_delta(&GraphDelta::AddEdge { u: 0, v: 5, w: 0.5 })
            .unwrap();
        let got: BTreeSet<(u32, u32)> = sum.resampled.iter().copied().collect();
        assert!(
            inv.is_subset(&got),
            "delta at the hub must resample its whole invalidation set"
        );
        let mut g2 = g;
        g2.add_edge(0, 5, 0.5);
        let full = StreamingFeatures::new(g2, cfg, f, 3);
        assert!(
            s.phi_snapshot() == full.phi_snapshot(),
            "hub-cap fallback broke bit-identity"
        );
    }

    /// Exactification at compaction: a hub saturated under heavy
    /// traffic returns to precise (strictly smaller) invalidation once
    /// its traffic shrinks, with bit-identical features throughout.
    #[test]
    fn compaction_exactifies_shrunken_hubs() {
        // Star: centre 0, spokes 1..=5. Every spoke walk visits the
        // centre, so with K=2 (cap = 16 < ~44 visitors) it saturates.
        let edges: Vec<(u32, u32, f64)> =
            (1..6).map(|i| (0, i, 1.0)).collect();
        let g = Graph::from_edges(6, &edges);
        let cfg = WalkConfig { n_walks: 8, max_len: 3, threads: 2, ..Default::default() };
        let f = vec![1.0, 0.5, 0.25, 0.125];
        let mut s = StreamingFeatures::new(g, cfg.clone(), f.clone(), 77);
        s.set_compact_threshold(usize::MAX);
        s.set_hub_cap(2);
        assert!(s.saturated_hubs() > 0, "star centre must saturate at K=2");
        // Shrink the hub's traffic: cut all spokes but 1. The stale
        // sources stay recorded (superset invariant), so the
        // invalidation set at the centre remains the full source
        // expansion until compaction.
        for v in 2..6 {
            s.apply_delta(&GraphDelta::RemoveEdge { u: 0, v }).unwrap();
        }
        let before = s.visiting_walks(&[0]);
        assert!(
            before.len() >= 2 * cfg.n_walks,
            "pre-compaction set should still carry stale sources"
        );
        s.compact();
        assert_eq!(
            s.saturated_hubs(),
            0,
            "all nodes fit under the cap after the cut"
        );
        let after = s.visiting_walks(&[0]);
        assert!(
            after.len() < before.len(),
            "exactified hub must resample strictly less: {} !< {}",
            after.len(),
            before.len()
        );
        assert!(
            after.is_subset(&before),
            "exact list must be a subset of the conservative expansion"
        );
        // Only walks of the two still-connected sources (and the
        // centre itself) can visit the centre now.
        for &(src, _) in &after {
            assert!(src == 0 || src == 1, "impossible visitor source {src}");
        }
        // Features were never touched by the index maintenance, and a
        // post-exactification delta stays bit-identical to a rebuild.
        let sum = s
            .apply_delta(&GraphDelta::AddEdge { u: 0, v: 3, w: 0.7 })
            .unwrap();
        let got: BTreeSet<(u32, u32)> = sum.resampled.iter().copied().collect();
        assert!(
            got.len() <= after.len() + 2 * cfg.n_walks,
            "exactified delta resampled more than visitors + endpoint walks"
        );
        let full = StreamingFeatures::new(
            s.graph().clone(),
            cfg,
            f,
            77,
        );
        assert!(
            s.phi_snapshot() == full.phi_snapshot(),
            "exactification broke bit-identity"
        );
    }

    #[test]
    fn delta_roundtrip_restores_state_bitwise() {
        // add_edge followed by remove_edge restores the graph, so the
        // resampled walks rerun their original trajectories and Φ must
        // come back bit-identical. A path graph guarantees (0, 9) is
        // initially absent.
        let edges: Vec<(u32, u32, f64)> =
            (0..13).map(|i| (i, i + 1, 1.0)).collect();
        let g = Graph::from_edges(14, &edges);
        let cfg = WalkConfig { n_walks: 8, max_len: 3, threads: 1, ..Default::default() };
        let f = vec![1.0, 0.5, 0.25, 0.125];
        let mut s = StreamingFeatures::new(g, cfg, f, 42);
        s.set_compact_threshold(usize::MAX);
        let before = s.phi_snapshot();
        s.apply_delta(&GraphDelta::AddEdge { u: 0, v: 9, w: 0.7 }).unwrap();
        assert!(s.phi_snapshot() != before, "delta should change Φ");
        s.apply_delta(&GraphDelta::RemoveEdge { u: 0, v: 9 }).unwrap();
        assert!(s.phi_snapshot() == before, "roundtrip must restore Φ bitwise");
    }

    #[test]
    fn add_node_rows_and_dimensions() {
        let mut rng = Rng::new(3);
        let (g, _) = random_graph(&mut rng, 10, 0.3);
        let cfg = WalkConfig { n_walks: 5, max_len: 2, threads: 1, ..Default::default() };
        let f = vec![2.0, 0.5, 0.25];
        let mut s = StreamingFeatures::new(g, cfg, f, 7);
        let sum = s.apply_delta(&GraphDelta::AddNode).unwrap();
        assert_eq!(sum.added_node, Some(10));
        assert_eq!(sum.resampled.len(), 5);
        let phi = s.phi_snapshot();
        assert_eq!(phi.n_rows, 11);
        assert_eq!(phi.n_cols, 11);
        // Isolated node: every walk deposits load 1.0 at l=0 only, so
        // its Φ row is exactly f_0 at the diagonal.
        let (cols, vals) = phi.row(10);
        assert_eq!(cols, &[10u32]);
        assert!((vals[0] - 2.0).abs() < 1e-12);
        // The new node can then be wired in.
        s.apply_delta(&GraphDelta::AddEdge { u: 10, v: 0, w: 1.0 }).unwrap();
        assert!(s.phi_snapshot().row(10).0.len() >= 1);
    }

    #[test]
    fn compaction_preserves_state_and_reselects_layout() {
        let mut rng = Rng::new(9);
        let (g, _) = random_graph(&mut rng, 16, 0.25);
        let cfg = WalkConfig { n_walks: 6, max_len: 3, threads: 1, ..Default::default() };
        let f = vec![1.0, 0.5, 0.25, 0.125];
        let mut s = StreamingFeatures::new(g, cfg, f, 5);
        s.set_compact_threshold(usize::MAX);
        for k in 0..4 {
            s.apply_delta(&GraphDelta::AddEdge { u: k, v: k + 5, w: 0.5 }).unwrap();
        }
        assert!(s.overlay_rows() > 0);
        let phi_overlay = s.phi_snapshot();
        let comps_overlay = s.components();
        s.compact();
        assert_eq!(s.overlay_rows(), 0);
        assert!(s.phi_snapshot() == phi_overlay, "compaction changed Φ");
        let comps = s.components();
        for l in 0..comps.c.len() {
            assert!(comps.c[l] == comps_overlay.c[l], "compaction changed C_{l}");
        }
        assert_eq!(s.compactions, 1);
        // Layout policy re-ran: under Auto on these near-uniform rows
        // it must produce *a* decision without disturbing Φ (the
        // operand is only a memory layout).
        let _ = s.phi_ell();
    }

    #[test]
    fn errors_leave_state_untouched() {
        let mut rng = Rng::new(11);
        let (g, _) = random_graph(&mut rng, 8, 0.4);
        let cfg = WalkConfig { n_walks: 4, max_len: 2, threads: 1, ..Default::default() };
        let mut s = StreamingFeatures::new(g, cfg, vec![1.0, 0.5, 0.25], 1);
        let before = s.phi_snapshot();
        assert!(s.apply_delta(&GraphDelta::AddEdge { u: 0, v: 99, w: 1.0 }).is_err());
        assert!(s
            .apply_delta(&GraphDelta::AddEdge { u: 0, v: 1, w: -1.0 })
            .is_err());
        // Removing a non-edge: find a non-adjacent pair.
        let mut non_edge = None;
        'outer: for u in 0..8 {
            for v in 0..8 {
                if u != v && s.graph().edge_weight(u, v) == 0.0 {
                    non_edge = Some((u, v));
                    break 'outer;
                }
            }
        }
        if let Some((u, v)) = non_edge {
            assert!(s.apply_delta(&GraphDelta::RemoveEdge { u, v }).is_err());
        }
        assert!(s.phi_snapshot() == before);
        assert_eq!(s.deltas_applied, 0);
    }

    #[test]
    fn modulation_swap_matches_fresh_build() {
        let mut rng = Rng::new(21);
        let (g, _) = random_graph(&mut rng, 12, 0.3);
        let cfg = WalkConfig { n_walks: 5, max_len: 2, threads: 1, ..Default::default() };
        let mut s = StreamingFeatures::new(g.clone(), cfg.clone(), vec![1.0, 0.5, 0.25], 3);
        s.set_compact_threshold(usize::MAX);
        s.apply_delta(&GraphDelta::AddEdge { u: 1, v: 7, w: 0.9 }).unwrap();
        let f2 = vec![0.3, 1.2, 0.8];
        s.set_modulation(f2.clone());
        let mut g2 = g;
        g2.add_edge(1, 7, 0.9);
        let full = StreamingFeatures::new(g2, cfg, f2, 3);
        assert!(s.phi_snapshot() == full.phi_snapshot());
    }
}
