//! RAII solve-path spans feeding the registry's latency histograms.
//!
//! A [`Span`] measures the wall time from construction to drop and
//! records it (in ns) into a `static` [`Histo`]. With telemetry
//! disabled a span is inert — it skips even the `Instant::now()`
//! call, so the disabled cost is one relaxed atomic load.
//!
//! [`timed`] is the closure form that *also returns* the measured
//! seconds, which is what lets the `exp` scenario drivers keep writing
//! durations into their JSON result files while feeding the same
//! numbers to the registry (one timing idiom; see
//! `util::timer::Stopwatch`'s deprecation note).

use super::registry::{enabled, Histo};
use std::time::Instant;

/// RAII timing guard: records elapsed ns into `h` on drop.
#[must_use = "a span records on drop; binding it to _ discards the measurement immediately"]
pub struct Span {
    h: &'static Histo,
    start: Option<Instant>,
}

impl Span {
    /// Start a span over `h` (inert when telemetry is disabled).
    #[inline]
    pub fn new(h: &'static Histo) -> Span {
        Span { h, start: enabled().then(Instant::now) }
    }

    /// Stop early and return the elapsed seconds (0.0 when inert).
    pub fn stop(mut self) -> f64 {
        self.finish()
    }

    fn finish(&mut self) -> f64 {
        match self.start.take() {
            Some(t0) => {
                let d = t0.elapsed();
                self.h.record_duration(d);
                d.as_secs_f64()
            }
            None => 0.0,
        }
    }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        self.finish();
    }
}

/// Time a closure, record the duration into `h`, and return
/// `(result, seconds)`. The seconds are measured (and returned) even
/// with telemetry disabled — callers writing results files must not
/// lose their numbers when recording is off; only the registry feed is
/// skipped.
#[inline]
pub fn timed<T>(h: &'static Histo, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    let d = t0.elapsed();
    h.record_duration(d);
    (v, d.as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::{set_enabled, test_lock, STOPWATCH_NS};

    // Delta-based: the registry is process-global (see registry tests).

    #[test]
    fn span_records_on_drop_and_stop_returns_seconds() {
        let _g = test_lock();
        let before = STOPWATCH_NS.count();
        {
            let _s = Span::new(&STOPWATCH_NS);
        }
        let secs = Span::new(&STOPWATCH_NS).stop();
        assert!(secs >= 0.0);
        assert_eq!(STOPWATCH_NS.count() - before, 2);
    }

    #[test]
    fn timed_returns_value_and_duration() {
        let _g = test_lock();
        let before = STOPWATCH_NS.count();
        let (v, secs) = timed(&STOPWATCH_NS, || 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
        assert_eq!(STOPWATCH_NS.count() - before, 1);
    }

    #[test]
    fn disabled_span_is_inert_but_timed_still_measures() {
        let _g = test_lock();
        set_enabled(false);
        let before = STOPWATCH_NS.count();
        let s = Span::new(&STOPWATCH_NS);
        assert!(s.start.is_none(), "disabled span must skip Instant::now");
        drop(s);
        let (_, secs) = timed(&STOPWATCH_NS, || std::thread::sleep(
            std::time::Duration::from_millis(1),
        ));
        set_enabled(true);
        assert_eq!(STOPWATCH_NS.count(), before, "no records while disabled");
        assert!(secs > 0.0, "timed must still measure while disabled");
    }
}
