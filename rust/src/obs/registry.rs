//! The global lock-free metrics registry.
//!
//! Every metric is a `static` made of plain `AtomicU64`s, declared in
//! one catalogue ([`all`]) so the export surface cannot drift from the
//! record sites. The record path (`inc`/`add`/`set`/`record`) is a
//! handful of relaxed atomic RMWs — no locks, no allocation, no
//! branching beyond the global [`enabled`] check — which is what makes
//! it safe inside the CG inner loop and on the wait-free predict path.
//!
//! ## Histogram bucket scheme
//!
//! [`Histo`] uses fixed log₂ buckets: a recorded value `v` lands in
//! bucket `bits(v) = 64 − v.leading_zeros()` (bucket 0 holds `v == 0`,
//! bucket `i ≥ 1` holds `v ∈ [2^(i-1), 2^i)`), clamped to
//! [`NUM_BUCKETS`]` − 1`. With 44 buckets the top bucket starts at
//! `2^42` ns ≈ 73 min — everything slower saturates there. Quantiles
//! ([`Histo::quantile`]) walk the buckets and return the upper bound
//! `2^i − 1` of the bucket containing the q-th sample — a ≤ 2×
//! overestimate by construction, which is the right bias for latency
//! alerting. Units are per-histogram ([`Unit::Nanos`] for spans,
//! [`Unit::Count`] for iteration/fan-out distributions) and exported
//! so renderers can convert.

use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Global telemetry switch — on by default. Off, every record site
/// early-returns after one relaxed load (the `telemetry_overhead`
/// bench row tracks both states).
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Is telemetry recording currently enabled?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip telemetry recording globally (scrapes keep working either
/// way — disabling only freezes the values).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Monotone event counter.
pub struct Counter {
    val: AtomicU64,
}

impl Counter {
    pub const fn new() -> Counter {
        Counter { val: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.val.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.val.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// Last-write-wins f64 gauge (stored as bits in one `AtomicU64`).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        // 0u64 is the bit pattern of +0.0, so a never-set gauge reads 0.
        Gauge { bits: AtomicU64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        if enabled() {
            self.bits.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

/// What a histogram's recorded values measure (drives rendering).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unit {
    /// Durations in nanoseconds (spans).
    Nanos,
    /// Dimensionless counts (CG iterations, resample fan-out, …).
    Count,
}

impl Unit {
    pub fn as_str(self) -> &'static str {
        match self {
            Unit::Nanos => "ns",
            Unit::Count => "count",
        }
    }
}

/// Fixed bucket count: log₂ buckets 0..=43 (top bucket opens at
/// 2^42 ns ≈ 73 min; larger values clamp into it).
pub const NUM_BUCKETS: usize = 44;

/// Log₂-bucket histogram: one `AtomicU64` per bucket plus a running
/// value sum. `record` is two relaxed `fetch_add`s — no allocation, no
/// lock. The count is *not* stored separately: exports derive it from
/// the buckets they just read, so an exported `count` always equals
/// the sum of the exported buckets even mid-traffic (see the module
/// docs of [`crate::obs`], "Torn-read discipline").
pub struct Histo {
    unit: Unit,
    buckets: [AtomicU64; NUM_BUCKETS],
    sum: AtomicU64,
}

/// Bucket index of a value (see module docs for the scheme).
#[inline]
fn bucket_index(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(NUM_BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i` (`u64::MAX` for the clamp
/// bucket).
pub fn bucket_bound(i: usize) -> u64 {
    if i >= NUM_BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

impl Histo {
    pub const fn new(unit: Unit) -> Histo {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histo { unit, buckets: [ZERO; NUM_BUCKETS], sum: AtomicU64::new(0) }
    }

    pub fn unit(&self) -> Unit {
        self.unit
    }

    /// Record one value (ns for [`Unit::Nanos`], a count otherwise).
    #[inline]
    pub fn record(&self, v: u64) {
        if enabled() {
            self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
            self.sum.fetch_add(v, Ordering::Relaxed);
        }
    }

    /// Record a duration (saturating past ~584 years).
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// One coherent read of the buckets (the unit of export).
    pub fn load_buckets(&self) -> [u64; NUM_BUCKETS] {
        let mut out = [0u64; NUM_BUCKETS];
        for (o, b) in out.iter_mut().zip(&self.buckets) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Total recorded samples (derived from one bucket read).
    pub fn count(&self) -> u64 {
        self.load_buckets().iter().sum()
    }

    /// Sum of all recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Quantile estimate (`q` in [0, 1]): the upper bound of the
    /// bucket containing the ⌈q·count⌉-th sample, or `None` when
    /// empty. See the module docs for the (≤ 2×, upward) bias.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        quantile_of(&self.load_buckets(), q)
    }
}

/// [`Histo::quantile`] over an already-loaded bucket array — exports
/// read the buckets once and derive count + every quantile from that
/// single read.
pub fn quantile_of(buckets: &[u64; NUM_BUCKETS], q: f64) -> Option<u64> {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return None;
    }
    let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
    let mut seen = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return Some(bucket_bound(i));
        }
    }
    Some(bucket_bound(NUM_BUCKETS - 1))
}

/// One registry entry: a name plus a reference to the static metric.
pub enum Metric {
    Counter(&'static str, &'static Counter),
    Gauge(&'static str, &'static Gauge),
    Histo(&'static str, &'static Histo),
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Counter(n, _) | Metric::Gauge(n, _) | Metric::Histo(n, _) => n,
        }
    }
}

macro_rules! catalogue {
    (
        counters: [ $( ($cstat:ident, $cname:literal) ),* $(,)? ],
        gauges:   [ $( ($gstat:ident, $gname:literal) ),* $(,)? ],
        histos:   [ $( ($hstat:ident, $hname:literal, $hunit:expr) ),* $(,)? ],
    ) => {
        $( pub static $cstat: Counter = Counter::new(); )*
        $( pub static $gstat: Gauge = Gauge::new(); )*
        $( pub static $hstat: Histo = Histo::new($hunit); )*

        /// Every metric in the registry, in catalogue order. This is
        /// the single source of truth for the export surface: a metric
        /// not listed here cannot be scraped.
        pub fn all() -> &'static [Metric] {
            static ALL: &[Metric] = &[
                $( Metric::Counter($cname, &$cstat), )*
                $( Metric::Gauge($gname, &$gstat), )*
                $( Metric::Histo($hname, &$hstat), )*
            ];
            ALL
        }
    };
}

catalogue! {
    counters: [
        // Per-op request counters (bumped once per wire-dispatched
        // request in `server::dispatch`).
        (REQ_OBSERVE, "req_observe"),
        (REQ_PREDICT, "req_predict"),
        (REQ_ADD_EDGE, "req_add_edge"),
        (REQ_REMOVE_EDGE, "req_remove_edge"),
        (REQ_ADD_NODE, "req_add_node"),
        (REQ_SAMPLE, "req_sample"),
        (REQ_THOMPSON, "req_thompson"),
        (REQ_STATS, "req_stats"),
        (REQ_METRICS, "req_metrics"),
        (REQ_SHUTDOWN, "req_shutdown"),
        (REQ_FAULT, "req_fault"),
        // Error replies by `error_kind` (wire + handler errors).
        (ERR_PARSE, "errors_parse"),
        (ERR_PROTOCOL, "errors_protocol"),
        (ERR_OVERLOAD, "errors_overload"),
        (ERR_INTERNAL, "errors_internal"),
        // Requests slower than `--slow-request-ms` (logged too).
        (SLOW_REQUESTS, "slow_requests"),
        // Solver traffic.
        (CG_SOLVES, "cg_solves"),
        (CG_BLOCK_SOLVES, "cg_block_solves"),
        (CG_NOCONVERGED, "cg_noconverged"),
        // SpMV/SpMM dispatches by selected layout.
        (SPMV_ELL, "spmv_ell"),
        (SPMV_CSR, "spmv_csr"),
        (SPMM_ELL, "spmm_ell"),
        (SPMM_CSR, "spmm_csr"),
        // Streaming delta engine.
        (STREAM_DELTA_BATCHES, "stream_delta_batches"),
        (STREAM_COMPACTIONS, "stream_compactions"),
        // Read-snapshot publications.
        (SNAPSHOT_PUBLISHES, "snapshot_publishes"),
        // Alert rules that fired at scrape time (`obs::alerts`).
        (ALERTS_FIRED, "alerts_fired"),
        // Per-shard delta-fan-out work (see `shard_metrics`): walks
        // resampled and feature rows patched by each shard worker.
        // Shards beyond slot 3 clamp into the last slot.
        (SHARD0_RESAMPLE_WALKS, "shard0_resample_walks"),
        (SHARD1_RESAMPLE_WALKS, "shard1_resample_walks"),
        (SHARD2_RESAMPLE_WALKS, "shard2_resample_walks"),
        (SHARD3_RESAMPLE_WALKS, "shard3_resample_walks"),
        (SHARD0_PATCH_ROWS, "shard0_patch_rows"),
        (SHARD1_PATCH_ROWS, "shard1_patch_rows"),
        (SHARD2_PATCH_ROWS, "shard2_patch_rows"),
        (SHARD3_PATCH_ROWS, "shard3_patch_rows"),
    ],
    gauges: [
        // Mean per-entry kernel-estimate variance across walk seeds,
        // one gauge per walk-termination scheme (`walks::Termination`)
        // so the correlated walkers publish next to the iid baseline
        // they must beat (`walks::kernel_variance`).
        (GRF_VARIANCE_IID, "grf_variance_iid"),
        (GRF_VARIANCE_ANTITHETIC, "grf_variance_antithetic"),
        (GRF_VARIANCE_QMC, "grf_variance_qmc"),
        // Relative residual of the most recent CG solve.
        (CG_LAST_RESIDUAL, "cg_last_residual"),
    ],
    histos: [
        // Per-request wall time by op, recorded at the wire dispatch
        // point (includes batching-window waits — the client-visible
        // latency).
        (REQUEST_NS_OBSERVE, "request_ns_observe", Unit::Nanos),
        (REQUEST_NS_PREDICT, "request_ns_predict", Unit::Nanos),
        (REQUEST_NS_ADD_EDGE, "request_ns_add_edge", Unit::Nanos),
        (REQUEST_NS_REMOVE_EDGE, "request_ns_remove_edge", Unit::Nanos),
        (REQUEST_NS_ADD_NODE, "request_ns_add_node", Unit::Nanos),
        (REQUEST_NS_SAMPLE, "request_ns_sample", Unit::Nanos),
        (REQUEST_NS_THOMPSON, "request_ns_thompson", Unit::Nanos),
        (REQUEST_NS_STATS, "request_ns_stats", Unit::Nanos),
        (REQUEST_NS_METRICS, "request_ns_metrics", Unit::Nanos),
        (REQUEST_NS_SHUTDOWN, "request_ns_shutdown", Unit::Nanos),
        (REQUEST_NS_FAULT, "request_ns_fault", Unit::Nanos),
        // CG: iterations-to-converge per solve (scalar and block), and
        // the residual trajectory as decades (−log₁₀ of the relative
        // residual, one sample per iteration of the scalar path plus
        // one per finished solve — how many digits each solve earns).
        (CG_ITERS, "cg_iters", Unit::Count),
        (CG_BLOCK_ITERS, "cg_block_iters", Unit::Count),
        (CG_RESIDUAL_DECADES, "cg_residual_decades", Unit::Count),
        // SpMV/SpMM dispatch time by selected layout.
        (SPMV_ELL_NS, "spmv_ell_ns", Unit::Nanos),
        (SPMV_CSR_NS, "spmv_csr_ns", Unit::Nanos),
        (SPMM_ELL_NS, "spmm_ell_ns", Unit::Nanos),
        (SPMM_CSR_NS, "spmm_csr_ns", Unit::Nanos),
        // Streaming delta engine: union resample fan-out (walks),
        // touched feature rows, resample + compaction durations.
        (RESAMPLE_WALKS, "resample_walks", Unit::Count),
        (RESAMPLE_ROWS, "resample_rows", Unit::Count),
        (RESAMPLE_NS, "resample_ns", Unit::Nanos),
        (COMPACT_NS, "compact_ns", Unit::Nanos),
        // Snapshot path: publish latency (build + swap) and the age of
        // the snapshot each predict computes off (predict-vs-publish
        // lag — the staleness the RCU read path actually delivers).
        (SNAPSHOT_PUBLISH_NS, "snapshot_publish_ns", Unit::Nanos),
        (PREDICT_SNAPSHOT_LAG_NS, "predict_snapshot_lag_ns", Unit::Nanos),
        // Experiment-driver phases (the one timing idiom: `exp`
        // scenarios time through `obs::span::timed` into these).
        (EXP_INIT_NS, "exp_init_ns", Unit::Nanos),
        (EXP_TRAIN_NS, "exp_train_ns", Unit::Nanos),
        (EXP_INFER_NS, "exp_infer_ns", Unit::Nanos),
        // Catch-all for the deprecated `util::timer::Stopwatch` shim.
        (STOPWATCH_NS, "stopwatch_ns", Unit::Nanos),
        // Per-shard resample wall time inside the delta fan-out (same
        // slot clamp as the shard counters).
        (SHARD0_RESAMPLE_NS, "shard0_resample_ns", Unit::Nanos),
        (SHARD1_RESAMPLE_NS, "shard1_resample_ns", Unit::Nanos),
        (SHARD2_RESAMPLE_NS, "shard2_resample_ns", Unit::Nanos),
        (SHARD3_RESAMPLE_NS, "shard3_resample_ns", Unit::Nanos),
    ],
}

/// Per-shard worker metrics `(resample_walks, patch_rows,
/// resample_ns)` for shard `s`. Four static slots are catalogued;
/// shards `s >= 3` share the last slot (the export stays bounded no
/// matter how many shards a deployment runs — per-shard resolution
/// for the first three, an aggregate tail for the rest).
pub fn shard_metrics(s: usize) -> (&'static Counter, &'static Counter, &'static Histo) {
    match s {
        0 => (&SHARD0_RESAMPLE_WALKS, &SHARD0_PATCH_ROWS, &SHARD0_RESAMPLE_NS),
        1 => (&SHARD1_RESAMPLE_WALKS, &SHARD1_PATCH_ROWS, &SHARD1_RESAMPLE_NS),
        2 => (&SHARD2_RESAMPLE_WALKS, &SHARD2_PATCH_ROWS, &SHARD2_RESAMPLE_NS),
        _ => (&SHARD3_RESAMPLE_WALKS, &SHARD3_PATCH_ROWS, &SHARD3_RESAMPLE_NS),
    }
}

/// The per-op request counter + latency histogram for a wire op name
/// (`None` for unknown ops — they only count as protocol errors).
pub fn request_metrics(op: &str) -> Option<(&'static Counter, &'static Histo)> {
    Some(match op {
        "observe" => (&REQ_OBSERVE, &REQUEST_NS_OBSERVE),
        "predict" => (&REQ_PREDICT, &REQUEST_NS_PREDICT),
        "add_edge" => (&REQ_ADD_EDGE, &REQUEST_NS_ADD_EDGE),
        "remove_edge" => (&REQ_REMOVE_EDGE, &REQUEST_NS_REMOVE_EDGE),
        "add_node" => (&REQ_ADD_NODE, &REQUEST_NS_ADD_NODE),
        "sample" => (&REQ_SAMPLE, &REQUEST_NS_SAMPLE),
        "thompson" => (&REQ_THOMPSON, &REQUEST_NS_THOMPSON),
        "stats" => (&REQ_STATS, &REQUEST_NS_STATS),
        "metrics" => (&REQ_METRICS, &REQUEST_NS_METRICS),
        "shutdown" => (&REQ_SHUTDOWN, &REQUEST_NS_SHUTDOWN),
        "fault" => (&REQ_FAULT, &REQUEST_NS_FAULT),
        _ => return None,
    })
}

/// The error counter for an `error_kind` wire string.
pub fn error_counter(kind: &str) -> Option<&'static Counter> {
    Some(match kind {
        "parse" => &ERR_PARSE,
        "protocol" => &ERR_PROTOCOL,
        "overload" => &ERR_OVERLOAD,
        "internal" => &ERR_INTERNAL,
        _ => return None,
    })
}

/// Record a relative residual into [`CG_RESIDUAL_DECADES`] as decades
/// (digits of accuracy): `1e-6` records 6. Non-positive/NaN residuals
/// clamp to 0 decades.
#[inline]
pub fn record_residual_decades(residual: f64) {
    let decades = if residual > 0.0 && residual.is_finite() {
        (-residual.log10()).clamp(0.0, 63.0)
    } else {
        0.0
    };
    CG_RESIDUAL_DECADES.record(decades as u64);
}

/// Export the whole registry as one JSON object:
/// `{"counters":{..},"gauges":{..},"histograms":{name:{unit,count,sum,
/// p50,p95,p99,buckets:[[le,count],..]}}}`. Lock-free: one relaxed
/// load per atomic; each histogram's `count`/quantiles derive from the
/// same single bucket read that is exported, so `count == Σ buckets`
/// holds even when scraped mid-traffic.
pub fn to_json() -> Json {
    let mut counters = Vec::new();
    let mut gauges = Vec::new();
    let mut histos = Vec::new();
    for m in all() {
        match m {
            Metric::Counter(name, c) => {
                counters.push((*name, Json::from_uint(c.get())));
            }
            Metric::Gauge(name, g) => {
                gauges.push((*name, Json::Num(g.get())));
            }
            Metric::Histo(name, h) => {
                histos.push((*name, histo_json(h)));
            }
        }
    }
    Json::obj(vec![
        ("counters", Json::obj(counters)),
        ("gauges", Json::obj(gauges)),
        ("histograms", Json::obj(histos)),
    ])
}

fn histo_json(h: &Histo) -> Json {
    let buckets = h.load_buckets();
    let count: u64 = buckets.iter().sum();
    let q = |p: f64| match quantile_of(&buckets, p) {
        Some(v) => Json::from_uint(v),
        None => Json::Null,
    };
    let nonzero: Vec<Json> = buckets
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(i, &c)| {
            Json::Arr(vec![
                // The clamp bucket's bound (u64::MAX) is not exactly
                // f64-representable; export it as a string token like
                // every other over-2^53 count.
                match Json::try_from_uint(bucket_bound(i)) {
                    Ok(j) => j,
                    Err(x) => Json::Str(x.to_string()),
                },
                Json::from_uint(c),
            ])
        })
        .collect();
    Json::obj(vec![
        ("unit", Json::Str(h.unit().as_str().to_string())),
        ("count", Json::from_uint(count)),
        ("sum", Json::from_uint(h.sum())),
        ("p50", q(0.50)),
        ("p95", q(0.95)),
        ("p99", q(0.99)),
        ("buckets", Json::Arr(nonzero)),
    ])
}

/// Serialises unit tests that record into (or toggle) the global
/// registry — without it, a test flipping [`set_enabled`] races any
/// concurrently running test asserting a recorded delta.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static M: std::sync::Mutex<()> = std::sync::Mutex::new(());
    M.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the registry is process-global and the crate's unit tests
    // run in parallel (CG tests record into CG_ITERS, …), so these
    // tests only assert *deltas* on metrics nothing else touches, or
    // pure functions — and every test that records or toggles the
    // enable flag holds `test_lock()`.

    #[test]
    fn bucket_index_scheme() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
        // Bounds are inclusive tops: bucket i covers (bound(i-1),
        // bound(i)].
        assert_eq!(bucket_bound(0), 0);
        assert_eq!(bucket_bound(1), 1);
        assert_eq!(bucket_bound(2), 3);
        assert_eq!(bucket_bound(10), 1023);
        assert_eq!(bucket_bound(NUM_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn histogram_records_and_quantiles() {
        let _g = test_lock();
        let h = Histo::new(Unit::Count);
        for v in [0u64, 1, 1, 2, 7, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 1111);
        // q=1.0 → the largest sample's bucket bound (1000 ∈ (511,
        // 1023]).
        assert_eq!(h.quantile(1.0), Some(1023));
        // q→0 → the smallest sample's bucket (0).
        assert_eq!(h.quantile(0.0), Some(0));
        // Median of 7 samples is the 4th (value 2 → bound 3).
        assert_eq!(h.quantile(0.5), Some(3));
        let empty = Histo::new(Unit::Nanos);
        assert_eq!(empty.quantile(0.5), None);
    }

    #[test]
    fn counter_and_gauge_deltas() {
        let _g = test_lock();
        let before = STREAM_COMPACTIONS.get();
        STREAM_COMPACTIONS.inc();
        STREAM_COMPACTIONS.add(2);
        assert_eq!(STREAM_COMPACTIONS.get() - before, 3);
        GRF_VARIANCE_IID.set(0.25);
        assert_eq!(GRF_VARIANCE_IID.get(), 0.25);
    }

    #[test]
    fn disabled_freezes_all_record_paths() {
        let _g = test_lock();
        let local = Histo::new(Unit::Nanos);
        let c = Counter::new();
        let g = Gauge::new();
        g.set(1.0);
        set_enabled(false);
        local.record(5);
        c.inc();
        g.set(9.0);
        set_enabled(true);
        assert_eq!(local.count(), 0);
        assert_eq!(c.get(), 0);
        assert_eq!(g.get(), 1.0);
    }

    #[test]
    fn catalogue_names_are_unique_and_lookups_hit_it() {
        let mut names: Vec<&str> = all().iter().map(|m| m.name()).collect();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate metric names in catalogue");
        // Every op/kind lookup resolves to a catalogued metric.
        for op in [
            "observe", "predict", "add_edge", "remove_edge", "add_node",
            "sample", "thompson", "stats", "metrics", "shutdown", "fault",
        ] {
            assert!(request_metrics(op).is_some(), "op {op} missing");
        }
        for kind in ["parse", "protocol", "overload", "internal"] {
            assert!(error_counter(kind).is_some(), "kind {kind} missing");
        }
        assert!(request_metrics("nope").is_none());
        assert!(error_counter("nope").is_none());
    }

    #[test]
    fn json_export_shape_and_internal_consistency() {
        let _g = test_lock();
        CG_ITERS.record(12);
        let j = to_json();
        for key in ["counters", "gauges", "histograms"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        let h = j.path(&["histograms", "cg_iters"]).expect("cg_iters");
        let count = h.get("count").and_then(Json::as_usize).unwrap();
        let bucket_total: usize = h
            .get("buckets")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|b| b.as_arr().unwrap()[1].as_usize().unwrap())
            .sum();
        assert_eq!(count, bucket_total, "count must equal Σ buckets");
        assert!(count >= 1);
    }
}
