//! p99 latency alerting off the registry's log₂ histograms.
//!
//! Rules come from `--alert-p99-ms op=ms[,op=ms...]` (one per request
//! op) and are **evaluated at scrape time** — the `{"op":"metrics"}`
//! wire op and the `--metrics-addr` HTTP endpoint both call
//! [`evaluate`] before rendering, so alerting costs nothing between
//! scrapes and needs no timer thread. Evaluation reads only atomics
//! (the histogram buckets), keeping the scrape path lock-free.
//!
//! A breached rule fires one structured single-line JSON record to
//! stderr ([`alert_record`], machine-parseable like the server's
//! `slow_request` records) and bumps the `alerts_fired` counter, so a
//! scraper can alert on the counter even if it drops stderr.
//!
//! The p99 is the registry's bucket-upper-bound estimate
//! ([`crate::obs::registry::Histo::quantile`]): biased upward by at
//! most 2×, never downward — a conservative trigger that cannot miss a
//! real breach at twice the limit.

use crate::obs::registry::{self, Histo};
use crate::util::json::Json;

/// One configured p99 limit for a request-op latency histogram.
#[derive(Clone)]
pub struct AlertRule {
    /// Request op the rule watches (e.g. `predict`).
    pub op: String,
    /// Fire when the op's p99 exceeds this many milliseconds.
    pub p99_limit_ms: u64,
    histo: &'static Histo,
}

impl std::fmt::Debug for AlertRule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlertRule")
            .field("op", &self.op)
            .field("p99_limit_ms", &self.p99_limit_ms)
            .finish()
    }
}

/// One fired alert (returned by [`evaluate`] for tests/callers; the
/// stderr record is the operational surface).
#[derive(Clone, Debug)]
pub struct Alert {
    pub op: String,
    /// Observed p99, in milliseconds (bucket upper bound).
    pub p99_ms: f64,
    pub p99_limit_ms: u64,
}

/// Parse a `--alert-p99-ms` spec: comma-separated `op=ms` pairs, ops
/// resolved against the request-metric catalogue
/// ([`registry::request_metrics`]). Unknown ops and malformed limits
/// are errors — a typo'd rule that silently never fires is worse than
/// a failed start.
pub fn parse_rules(spec: &str) -> Result<Vec<AlertRule>, String> {
    let mut rules = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (op, limit) = part
            .split_once('=')
            .ok_or_else(|| format!("alert rule '{part}': expected op=ms"))?;
        let op = op.trim();
        let p99_limit_ms: u64 = limit.trim().parse().map_err(|_| {
            format!("alert rule '{part}': bad millisecond limit '{}'", limit.trim())
        })?;
        let (_, histo) = registry::request_metrics(op)
            .ok_or_else(|| format!("alert rule '{part}': unknown op '{op}'"))?;
        rules.push(AlertRule {
            op: op.to_string(),
            p99_limit_ms,
            histo,
        });
    }
    Ok(rules)
}

/// The structured single-line record logged (to stderr) for a breach.
/// Split out so the shape is unit-testable.
pub fn alert_record(a: &Alert) -> Json {
    Json::obj(vec![
        ("alert", Json::Bool(true)),
        ("metric", Json::Str(format!("request_ns_{}", a.op))),
        ("op", Json::Str(a.op.clone())),
        ("p99_ms", Json::Num(a.p99_ms)),
        ("limit_ms", Json::from_uint(a.p99_limit_ms)),
    ])
}

/// Check every rule against the live registry. Each breach bumps
/// `alerts_fired` and logs one [`alert_record`] line to stderr; an
/// empty histogram (no traffic yet) never fires. Atomics only.
pub fn evaluate(rules: &[AlertRule]) -> Vec<Alert> {
    let mut fired = Vec::new();
    for rule in rules {
        let Some(p99_ns) = rule.histo.quantile(0.99) else {
            continue;
        };
        let p99_ms = p99_ns as f64 / 1e6;
        if p99_ms > rule.p99_limit_ms as f64 {
            registry::ALERTS_FIRED.inc();
            let alert = Alert {
                op: rule.op.clone(),
                p99_ms,
                p99_limit_ms: rule.p99_limit_ms,
            };
            let record = alert_record(&alert).to_string();
            eprintln!("{record}");
            fired.push(alert);
        }
    }
    fired
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    #[test]
    fn parse_rules_accepts_pairs_and_rejects_junk() {
        let rules = parse_rules("predict=50, add_edge=120 ,").unwrap();
        assert_eq!(rules.len(), 2);
        assert_eq!(rules[0].op, "predict");
        assert_eq!(rules[0].p99_limit_ms, 50);
        assert_eq!(rules[1].op, "add_edge");
        assert_eq!(rules[1].p99_limit_ms, 120);
        assert!(parse_rules("predict").is_err(), "missing =ms");
        assert!(parse_rules("predict=fast").is_err(), "non-numeric limit");
        assert!(parse_rules("warp_drive=5").is_err(), "unknown op");
        assert!(parse_rules("").unwrap().is_empty());
    }

    #[test]
    fn p99_breaches_fire_once_per_scrape_and_count() {
        let _guard = registry::test_lock();
        let was_enabled = obs::enabled();
        obs::set_enabled(true);
        // Synthetic fill of the (test-only) fault op's histogram: a
        // crowd of fast requests and >1% slow outliers put the p99 in
        // the slow bucket (upper bound 2^24 - 1 ns ≈ 16.8 ms). Sized
        // relative to any samples other tests already recorded — the
        // registry's statics persist across tests in one binary.
        let (_, h) = registry::request_metrics("fault").unwrap();
        let prior = h.count();
        let slow = (prior + 99) / 50 + 1;
        for _ in 0..99 {
            h.record(100_000); // 0.1 ms
        }
        for _ in 0..slow {
            h.record(10_000_000); // 10 ms → bucket top ≈ 16.8 ms
        }
        let p99_ms = h.quantile(0.99).unwrap() as f64 / 1e6;
        assert!(p99_ms > 10.0, "synthetic fill missed the slow bucket");

        let rules = parse_rules("fault=5").unwrap();
        let before = registry::ALERTS_FIRED.get();
        let fired = evaluate(&rules);
        assert_eq!(fired.len(), 1, "limit below p99 must fire");
        assert_eq!(registry::ALERTS_FIRED.get(), before + 1);
        assert_eq!(fired[0].op, "fault");
        assert!(fired[0].p99_ms > 5.0);

        // A generous limit stays quiet; so does an op with no traffic
        // (quantile of an empty histogram is None — only checkable
        // when no other test in this binary has recorded shutdowns).
        let mut spec = String::from("fault=60000");
        if registry::request_metrics("shutdown").unwrap().1.count() == 0 {
            spec.push_str(",shutdown=1");
        }
        let quiet = parse_rules(&spec).unwrap();
        let before = registry::ALERTS_FIRED.get();
        assert!(
            evaluate(&quiet).is_empty(),
            "limit above p99 / empty histogram must not fire"
        );
        assert_eq!(registry::ALERTS_FIRED.get(), before);

        // The stderr record is one flat JSON object with the fields a
        // log pipeline keys on.
        let rec = alert_record(&fired[0]).to_string();
        assert!(rec.contains("\"alert\":true"));
        assert!(rec.contains("\"metric\":\"request_ns_fault\""));
        assert!(rec.contains("\"limit_ms\":5"));
        assert!(!rec.contains('\n'));
        obs::set_enabled(was_enabled);
    }
}
