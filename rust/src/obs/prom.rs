//! Prometheus text rendering of the registry.
//!
//! [`render`] walks [`registry::all`] and emits the
//! [text exposition format](https://prometheus.io/docs/instrumenting/exposition_formats/):
//! a `# TYPE` line per metric, `name value` samples for counters and
//! gauges, and cumulative `_bucket{le="…"}` / `_sum` / `_count`
//! triples for histograms. Metric names are prefixed `grfgp_` and
//! suffixed with the histogram's unit (`_ns` histograms keep their
//! name; counts render as-is), so one scrape endpoint
//! (`{"op":"metrics","format":"prometheus"}`) plugs into a standard
//! scrape config:
//!
//! ```text
//! scrape_configs:
//!   - job_name: grfgp
//!     # a shim converting the newline-JSON op into an HTTP GET:
//!     #   echo '{"op":"metrics","format":"prometheus"}' | nc host 7701
//! ```

use super::registry::{self, bucket_bound, Metric, NUM_BUCKETS};
use std::fmt::Write as _;

/// Prometheus metric-name prefix for everything this crate exports.
pub const PREFIX: &str = "grfgp_";

/// Render the full registry in the Prometheus text exposition format.
/// Lock-free (same read discipline as [`registry::to_json`]); each
/// histogram is rendered from a single bucket read, so its `_count`
/// equals its `+Inf` cumulative bucket even when scraped mid-traffic.
pub fn render() -> String {
    let mut out = String::with_capacity(4096);
    for m in registry::all() {
        match m {
            Metric::Counter(name, c) => {
                let _ = writeln!(out, "# TYPE {PREFIX}{name} counter");
                let _ = writeln!(out, "{PREFIX}{name} {}", c.get());
            }
            Metric::Gauge(name, g) => {
                let _ = writeln!(out, "# TYPE {PREFIX}{name} gauge");
                let _ = writeln!(out, "{PREFIX}{name} {}", fmt_f64(g.get()));
            }
            Metric::Histo(name, h) => {
                let buckets = h.load_buckets();
                let count: u64 = buckets.iter().sum();
                let _ = writeln!(out, "# TYPE {PREFIX}{name} histogram");
                // Cumulative buckets, up to the last nonzero (plus the
                // mandatory +Inf bound). The top clamp bucket has no
                // finite bound, so it only ever renders as +Inf.
                let last = buckets
                    .iter()
                    .rposition(|&c| c > 0)
                    .map(|i| i.min(NUM_BUCKETS - 2));
                let mut cum = 0u64;
                if let Some(last) = last {
                    for (i, &c) in buckets.iter().enumerate().take(last + 1) {
                        cum += c;
                        let _ = writeln!(
                            out,
                            "{PREFIX}{name}_bucket{{le=\"{}\"}} {cum}",
                            bucket_bound(i)
                        );
                    }
                }
                let _ = writeln!(
                    out,
                    "{PREFIX}{name}_bucket{{le=\"+Inf\"}} {count}"
                );
                let _ = writeln!(out, "{PREFIX}{name}_sum {}", h.sum());
                let _ = writeln!(out, "{PREFIX}{name}_count {count}");
            }
        }
    }
    out
}

/// Prometheus float formatting: finite values via Rust's shortest
/// round-trip `{}`, specials as the format's `NaN`/`+Inf`/`-Inf`.
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Validate a Prometheus text exposition — every line must be a
/// comment or `name[{labels}] value`, histograms cumulative and
/// `_count`-consistent. Not a full parser; it is the structural check
/// the schema smoke test (and any future CI lint) runs against
/// [`render`]'s output, so format drift fails a test instead of a
/// scrape.
pub fn validate(text: &str) -> Result<(), String> {
    fn valid_name(s: &str) -> bool {
        !s.is_empty()
            && s.chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            && !s.starts_with(|c: char| c.is_ascii_digit())
    }
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name_part, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value: {line:?}", ln + 1))?;
        let name = match name_part.split_once('{') {
            Some((n, labels)) => {
                if !labels.ends_with('}') {
                    return Err(format!(
                        "line {}: unterminated labels: {line:?}",
                        ln + 1
                    ));
                }
                n
            }
            None => name_part,
        };
        if !valid_name(name) {
            return Err(format!("line {}: bad metric name {name:?}", ln + 1));
        }
        let ok_value = value.parse::<f64>().is_ok()
            || matches!(value, "NaN" | "+Inf" | "-Inf");
        if !ok_value {
            return Err(format!("line {}: bad value {value:?}", ln + 1));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::{test_lock, CG_ITERS, REQ_STATS};

    #[test]
    fn render_is_valid_and_covers_the_catalogue() {
        let _g = test_lock();
        REQ_STATS.inc();
        CG_ITERS.record(9);
        let text = render();
        validate(&text).expect("render must satisfy its own validator");
        for m in registry::all() {
            assert!(
                text.contains(&format!("# TYPE {PREFIX}{}", m.name())),
                "metric {} missing from rendering",
                m.name()
            );
        }
        // Histogram triple present and cumulative-bucket shaped.
        assert!(text.contains("grfgp_cg_iters_bucket{le=\"+Inf\"}"));
        assert!(text.contains("grfgp_cg_iters_sum"));
        assert!(text.contains("grfgp_cg_iters_count"));
    }

    #[test]
    fn validator_rejects_garbage() {
        assert!(validate("ok_metric 1\n").is_ok());
        assert!(validate("# any comment\n").is_ok());
        assert!(validate("novalue\n").is_err());
        assert!(validate("bad name 1 2 oops\n").is_err());
        assert!(validate("m{le=\"1\" 3\n").is_err(), "unterminated labels");
        assert!(validate("m NaNope\n").is_err());
        assert!(validate("9starts_with_digit 1\n").is_err());
    }
}
