//! First-class telemetry — lock-free metrics, solve-path spans, and
//! the wire-exported observability surface.
//!
//! The paper's headline claim is `O(N^{3/2})` inference; this module
//! is how the repo *watches* that claim hold under load. Three pieces:
//!
//! * [`registry`] — a global, dependency-free metrics registry of
//!   named atomic [`registry::Counter`]s, [`registry::Gauge`]s, and
//!   fixed-bucket log₂-scale latency [`registry::Histo`]grams. The
//!   record path is **lock-free and allocation-free**: every metric is
//!   a `static` of plain `AtomicU64`s (one per histogram bucket), so
//!   recording is a handful of relaxed `fetch_add`s — safe inside the
//!   CG inner loop and on the wait-free predict path. p50/p95/p99 are
//!   derived from the buckets at *export* time, never maintained on
//!   the hot path.
//! * [`span`] — RAII timing guards ([`span::Span`]) and a
//!   [`span::timed`] closure helper feeding the histograms. These
//!   instrument the layers that define the `N^{3/2}` story: CG
//!   iterations-to-converge and residual decades per solve
//!   (`linalg::cg`), SpMV/SpMM dispatch time by layout
//!   (`sparse::RowOverlay`), delta-batch resample fan-out and
//!   compaction duration (`stream`), snapshot publish latency and
//!   predict-vs-publish lag (`server::snapshot`), and per-request wall
//!   time by op (`server`).
//! * [`prom`] — a Prometheus-text rendering of the registry, served
//!   (with the JSON form) by the server's `{"op":"metrics"}` wire op
//!   and by the dependency-free `--metrics-addr` HTTP exposition
//!   listener.
//! * [`alerts`] — configurable p99 latency limits per request op
//!   (`--alert-p99-ms`), evaluated against the histograms at scrape
//!   time; breaches log one structured JSON record and bump
//!   `alerts_fired`.
//!
//! Telemetry is **on by default** and can be flipped off globally with
//! [`set_enabled`] (a single `AtomicBool` checked at each record
//! site); the `telemetry_overhead` bench row in `benches/hotpath.rs`
//! tracks the cost of both states, and `tests/obs.rs` asserts the
//! record path performs zero heap allocations and that the predict
//! path still takes zero model locks with telemetry enabled.
//!
//! ## Torn-read discipline
//!
//! The registry has no global lock, so a scrape concurrent with
//! traffic cannot be an atomic snapshot across *different* metrics.
//! What it does guarantee, by construction: each exported histogram's
//! `count` is computed from the very bucket values exported next to it
//! (`count == Σ buckets`, always, even mid-traffic), and counters are
//! monotone — two consecutive scrapes never observe a counter going
//! backwards. `tests/obs.rs` asserts both under concurrent load.

pub mod alerts;
pub mod prom;
pub mod registry;
pub mod span;

pub use registry::{enabled, set_enabled};
