//! Synthetic graph generators for every substrate the paper's
//! experiments need: rings, 2-D grids/meshes, stochastic block models,
//! Barabási–Albert preferential attachment, k-NN graphs on the sphere,
//! and a planar road-network generator (traffic substitute).

use super::Graph;
use crate::util::rng::Rng;

/// Ring (cycle) graph of n nodes, unit weights — the paper's scaling
/// substrate (App. C.2).
pub fn ring(n: usize) -> Graph {
    assert!(n >= 3);
    let edges: Vec<(u32, u32, f64)> = (0..n)
        .map(|i| (i as u32, ((i + 1) % n) as u32, 1.0))
        .collect();
    Graph::from_edges(n, &edges)
}

/// 4-connected rows x cols grid (the paper's 30x30 mesh / 1000x1000 BO
/// grids), unit weights.
pub fn grid2d(rows: usize, cols: usize) -> Graph {
    let n = rows * cols;
    let id = |r: usize, c: usize| (r * cols + c) as u32;
    let mut edges = Vec::with_capacity(2 * n);
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((id(r, c), id(r, c + 1), 1.0));
            }
            if r + 1 < rows {
                edges.push((id(r, c), id(r + 1, c), 1.0));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Stochastic block model: `sizes[c]` nodes per community, edge
/// probability `p_in` within and `p_out` across communities.
/// Returns (graph, community label per node).
pub fn sbm(sizes: &[usize], p_in: f64, p_out: f64, rng: &mut Rng) -> (Graph, Vec<usize>) {
    let n: usize = sizes.iter().sum();
    let mut labels = Vec::with_capacity(n);
    for (c, &sz) in sizes.iter().enumerate() {
        labels.extend(std::iter::repeat(c).take(sz));
    }
    // Segment boundaries: labels are block-contiguous, so each row's
    // columns split into runs of constant edge probability. Geometric
    // skipping must stay *within* a run (restarting at each boundary)
    // or edges near boundaries are sampled at the wrong rate.
    let mut bounds = Vec::with_capacity(sizes.len() + 1);
    bounds.push(0usize);
    for &s in sizes {
        bounds.push(bounds.last().unwrap() + s);
    }
    let mut edges = Vec::new();
    for i in 0..n {
        for c in 0..sizes.len() {
            let (seg_start, seg_end) = (bounds[c].max(i + 1), bounds[c + 1]);
            if seg_start >= seg_end {
                continue;
            }
            let p = if labels[i] == c { p_in } else { p_out };
            if p <= 0.0 {
                continue;
            }
            if p >= 1.0 {
                for j in seg_start..seg_end {
                    edges.push((i as u32, j as u32, 1.0));
                }
                continue;
            }
            let mut j = seg_start;
            loop {
                // Geometric skip: next edge at distance ~ Geom(p).
                let u = rng.uniform().max(1e-300);
                let skip = (u.ln() / (1.0 - p).ln()).floor() as usize;
                j += skip;
                if j >= seg_end {
                    break;
                }
                edges.push((i as u32, j as u32, 1.0));
                j += 1;
            }
        }
    }
    (Graph::from_edges(n, &edges), labels)
}

/// Degree-corrected-ish SBM used for the Cora substitute: same API but
/// `p_in`/`p_out` scaled per-node by a heavy-ish degree propensity.
pub fn dcsbm(sizes: &[usize], avg_within: f64, avg_across: f64, rng: &mut Rng) -> (Graph, Vec<usize>) {
    let n: usize = sizes.iter().sum();
    let mut labels = Vec::with_capacity(n);
    for (c, &sz) in sizes.iter().enumerate() {
        labels.extend(std::iter::repeat(c).take(sz));
    }
    // Propensity theta_i ~ 0.25 + Exp(1), normalized per community.
    let theta: Vec<f64> = (0..n)
        .map(|_| 0.25 + -rng.uniform().max(1e-12).ln())
        .collect();
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let base = if labels[i] == labels[j] { avg_within } else { avg_across };
            let p = (base * theta[i] * theta[j] / (n as f64)).min(0.9);
            if rng.bernoulli(p) {
                edges.push((i as u32, j as u32, 1.0));
            }
        }
    }
    (Graph::from_edges(n, &edges), labels)
}

/// Barabási–Albert preferential attachment: n nodes, each new node
/// attaching `m` edges. Heavy-tailed degrees — the SNAP social-network
/// substitute (DESIGN.md §5).
pub fn barabasi_albert(n: usize, m: usize, rng: &mut Rng) -> Graph {
    assert!(n > m && m >= 1);
    // repeated-nodes list implements preferential attachment in O(1)
    // per draw.
    let mut repeated: Vec<u32> = Vec::with_capacity(2 * n * m);
    let mut edges: Vec<(u32, u32, f64)> = Vec::with_capacity(n * m);
    // Seed clique of m+1 nodes.
    for i in 0..=(m as u32) {
        for j in (i + 1)..=(m as u32) {
            edges.push((i, j, 1.0));
            repeated.push(i);
            repeated.push(j);
        }
    }
    for v in (m + 1)..n {
        let mut chosen = Vec::with_capacity(m);
        while chosen.len() < m {
            let t = repeated[rng.below(repeated.len())];
            if !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            edges.push((v as u32, t, 1.0));
            repeated.push(v as u32);
            repeated.push(t);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Points on the unit sphere arranged as a lat/lon grid with `res_deg`
/// spacing (the paper's 2.5° ERA5 discretisation). Returns (points,
/// lat_deg, lon_deg).
pub fn sphere_grid(res_deg: f64) -> Vec<[f64; 3]> {
    let mut pts = Vec::new();
    let n_lat = (180.0 / res_deg) as usize;
    let n_lon = (360.0 / res_deg) as usize;
    for la in 0..n_lat {
        let lat = -90.0 + (la as f64 + 0.5) * res_deg;
        for lo in 0..n_lon {
            let lon = -180.0 + lo as f64 * res_deg;
            let (latr, lonr) = (lat.to_radians(), lon.to_radians());
            pts.push([
                latr.cos() * lonr.cos(),
                latr.cos() * lonr.sin(),
                latr.sin(),
            ]);
        }
    }
    pts
}

/// Symmetric k-nearest-neighbour graph over 3-D points; weight 1 on
/// every kept edge (matching the paper's unweighted kNN construction).
/// Brute force O(N^2) with a partial select — fine up to ~20K points.
pub fn knn_graph(points: &[[f64; 3]], k: usize) -> Graph {
    let n = points.len();
    let mut edges = Vec::with_capacity(n * k);
    for i in 0..n {
        let mut dists: Vec<(f64, u32)> = (0..n)
            .filter(|&j| j != i)
            .map(|j| {
                let d: f64 = (0..3)
                    .map(|a| (points[i][a] - points[j][a]).powi(2))
                    .sum();
                (d, j as u32)
            })
            .collect();
        let kth = k.min(dists.len());
        dists.select_nth_unstable_by(kth - 1, |a, b| a.0.total_cmp(&b.0));
        for &(_, j) in &dists[..kth] {
            let (a, b) = (i as u32, j);
            edges.push((a.min(b), a.max(b), 1.0));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Ring discretised as a k-NN graph (the paper's "circular graph,
/// 10^6 nodes" BO benchmark): each node connects to its k nearest
/// neighbours along the circle.
pub fn circular_knn(n: usize, k: usize) -> Graph {
    let half = (k / 2).max(1);
    let mut edges = Vec::with_capacity(n * half);
    for i in 0..n {
        for d in 1..=half {
            let j = (i + d) % n;
            edges.push((i as u32, j as u32, 1.0));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Planar road-network generator (San Jose traffic substitute):
/// a jittered coarse grid of "city blocks" plus diagonal freeway spines,
/// randomly pruned to reach the target edge density. Returns
/// (graph, positions, road_class per node) where class 1 = freeway.
pub fn road_network(
    target_nodes: usize,
    target_edges: usize,
    rng: &mut Rng,
) -> (Graph, Vec<[f64; 2]>, Vec<u8>) {
    // Grid dimensions chosen so rows*cols ≈ target_nodes.
    let cols = (target_nodes as f64).sqrt().round() as usize;
    let rows = target_nodes.div_ceil(cols);
    let n = rows * cols;
    let id = |r: usize, c: usize| (r * cols + c) as u32;
    let mut pos = Vec::with_capacity(n);
    for r in 0..rows {
        for c in 0..cols {
            pos.push([
                c as f64 + 0.3 * (rng.uniform() - 0.5),
                r as f64 + 0.3 * (rng.uniform() - 0.5),
            ]);
        }
    }
    // Freeway spines: two diagonals crossing the city.
    let mut class = vec![0u8; n];
    let mut edges: Vec<(u32, u32, f64)> = Vec::new();
    let spine = |points: Vec<(usize, usize)>, edges: &mut Vec<(u32, u32, f64)>, class: &mut Vec<u8>| {
        for w in points.windows(2) {
            let (a, b) = (id(w[0].0, w[0].1), id(w[1].0, w[1].1));
            edges.push((a, b, 1.0));
            class[a as usize] = 1;
            class[b as usize] = 1;
        }
    };
    spine(
        (0..rows.min(cols)).map(|i| (i, i)).collect(),
        &mut edges,
        &mut class,
    );
    spine(
        (0..rows.min(cols)).map(|i| (i, cols - 1 - i)).collect(),
        &mut edges,
        &mut class,
    );
    // City streets: grid edges kept with probability tuned to hit the
    // edge target (roads are sparse: avg degree ~2.3 in the paper).
    let grid_edge_count = rows * (cols - 1) + (rows - 1) * cols;
    let keep_p = ((target_edges.saturating_sub(edges.len())) as f64
        / grid_edge_count as f64)
        .min(1.0);
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols && rng.bernoulli(keep_p) {
                edges.push((id(r, c), id(r, c + 1), 1.0));
            }
            if r + 1 < rows && rng.bernoulli(keep_p) {
                edges.push((id(r, c), id(r + 1, c), 1.0));
            }
        }
    }
    let g = Graph::from_edges(n, &edges);
    // Keep only the largest connected component so GP inference is on
    // one graph (the paper's network is connected).
    let (g, keep) = super::stats::largest_component(&g);
    let pos = keep.iter().map(|&i| pos[i]).collect();
    let class = keep.iter().map(|&i| class[i]).collect();
    (g, pos, class)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_degrees() {
        let g = ring(10);
        assert_eq!(g.num_nodes(), 10);
        assert_eq!(g.num_edges(), 10);
        for i in 0..10 {
            assert_eq!(g.degree(i), 2);
        }
        g.validate().unwrap();
    }

    #[test]
    fn grid_structure() {
        let g = grid2d(3, 4);
        assert_eq!(g.num_nodes(), 12);
        assert_eq!(g.num_edges(), 3 * 3 + 2 * 4);
        assert_eq!(g.degree(0), 2); // corner
        assert_eq!(g.degree(5), 4); // interior
        g.validate().unwrap();
    }

    #[test]
    fn sbm_community_structure() {
        let mut rng = Rng::new(0);
        let (g, labels) = sbm(&[50, 50], 0.3, 0.01, &mut rng);
        g.validate().unwrap();
        assert_eq!(labels.len(), 100);
        // Count within vs across edges.
        let (mut within, mut across) = (0, 0);
        for i in 0..100 {
            for &j in g.neighbors(i) {
                if labels[i] == labels[j as usize] {
                    within += 1;
                } else {
                    across += 1;
                }
            }
        }
        assert!(within > 8 * across, "within={within} across={across}");
    }

    #[test]
    fn ba_heavy_tail() {
        let mut rng = Rng::new(1);
        let g = barabasi_albert(2000, 3, &mut rng);
        g.validate().unwrap();
        assert_eq!(g.num_nodes(), 2000);
        // Max degree should greatly exceed average (heavy tail).
        assert!(g.max_degree() as f64 > 5.0 * g.avg_degree());
    }

    #[test]
    fn knn_graph_symmetric_connected_ring() {
        let pts: Vec<[f64; 3]> = (0..60)
            .map(|i| {
                let t = i as f64 / 60.0 * std::f64::consts::TAU;
                [t.cos(), t.sin(), 0.0]
            })
            .collect();
        let g = knn_graph(&pts, 2);
        g.validate().unwrap();
        let (comp, _) = super::super::stats::largest_component(&g);
        assert_eq!(comp.num_nodes(), 60);
    }

    #[test]
    fn circular_knn_degree() {
        let g = circular_knn(100, 4);
        g.validate().unwrap();
        for i in 0..100 {
            assert_eq!(g.degree(i), 4);
        }
    }

    #[test]
    fn road_network_matches_paper_shape() {
        let mut rng = Rng::new(7);
        let (g, pos, class) = road_network(1016, 1173, &mut rng);
        g.validate().unwrap();
        assert_eq!(pos.len(), g.num_nodes());
        assert_eq!(class.len(), g.num_nodes());
        // Should be in the right ballpark (connected component pruning
        // trims some nodes).
        assert!(g.num_nodes() > 700, "nodes={}", g.num_nodes());
        assert!(g.avg_degree() < 3.5, "avg degree={}", g.avg_degree());
        assert!(class.iter().any(|&c| c == 1));
    }

    #[test]
    fn sphere_grid_point_count() {
        let pts = sphere_grid(10.0);
        assert_eq!(pts.len(), 18 * 36);
        for p in &pts {
            let norm: f64 = p.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-9);
        }
    }
}
