//! Graph substrate: CSR adjacency, generators, I/O, statistics.

pub mod generators;
pub mod io;
pub mod stats;

/// Undirected weighted graph in CSR (compressed sparse row) form with a
/// staged per-row edge buffer for mutations.
///
/// Both directions of every undirected edge are stored, so `neighbors(i)`
/// is a single contiguous slice. Node ids are `u32` (graphs up to ~4B
/// nodes; the paper's largest is 1.13M).
///
/// ## Per-row edge buffer (streaming mutations)
///
/// A structural `add_edge`/`remove_edge` does **not** splice the global
/// CSR arrays (that costs O(N + nnz) in offset shifts and `Vec::insert`
/// moves). Instead the touched row is *staged*: its full sorted content
/// is copied out once (copy-on-write, O(deg)) into [`Graph::staged`],
/// and further mutations of that row edit the staged copy in place
/// (O(deg) per insert/remove after an O(log deg) search). Invariants:
///
/// * a staged row always holds the row's **complete** current adjacency,
///   sorted by target with duplicates merged — exactly the canonical
///   CSR row shape — so every read path returns contiguous slices with
///   identical content and ordering to a freshly built CSR (walk
///   determinism depends on that ordering);
/// * the base CSR arrays keep the *pre-staging* content of staged rows
///   (dead storage until [`Graph::compact`]); all accessors route
///   through [`Graph::row`], which prefers the staged copy;
/// * `n_directed` tracks the live directed-entry count across base +
///   staged rows (`targets.len()` whenever no row is staged);
/// * weight-only reinforcement of an existing entry mutates in place
///   (base or staged) — no staging needed, the structure is unchanged.
///
/// [`Graph::compact`] folds the staged rows back into one canonical CSR
/// in a single O(nnz) pass; the streaming subsystem calls it alongside
/// its own feature-overlay compaction.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Row pointer, length n+1 (base CSR; see the staging invariants).
    pub offsets: Vec<usize>,
    /// Column indices (neighbor ids), length 2|E| of the base CSR.
    pub targets: Vec<u32>,
    /// Edge weights, parallel to `targets`.
    pub weights: Vec<f64>,
    /// Staged copy-on-write rows: node id → full sorted row content,
    /// overriding the base CSR row until the next `compact()`.
    staged: std::collections::BTreeMap<u32, StagedRow>,
    /// Live directed entries across base + staged rows.
    n_directed: usize,
    /// Live self-loop entries (stored once each) — keeps `num_edges`
    /// O(1) instead of an O(N) per-node scan.
    n_self_loops: usize,
}

/// One staged adjacency row (full sorted content, see [`Graph`] docs).
#[derive(Clone, Debug, Default)]
struct StagedRow {
    targets: Vec<u32>,
    weights: Vec<f64>,
}

impl Graph {
    /// Build from an undirected edge list. Duplicate edges are summed;
    /// self-loops are kept as single directed entries.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f64)]) -> Graph {
        // Node ids are stored as u32 throughout (CSR targets, staged
        // rows): guard the ceiling here so every later `as u32` cast
        // on an index < n is provably lossless instead of wrapping.
        assert!(
            u32::try_from(n).is_ok(),
            "graph node count {n} exceeds the u32 id space"
        );
        let mut deg = vec![0usize; n];
        for &(a, b, _) in edges {
            assert!((a as usize) < n && (b as usize) < n, "edge out of range");
            deg[a as usize] += 1;
            if a != b {
                deg[b as usize] += 1;
            }
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let nnz = offsets[n];
        let mut targets = vec![0u32; nnz];
        let mut weights = vec![0f64; nnz];
        let mut cursor = offsets.clone();
        for &(a, b, w) in edges {
            targets[cursor[a as usize]] = b;
            weights[cursor[a as usize]] = w;
            cursor[a as usize] += 1;
            if a != b {
                targets[cursor[b as usize]] = a;
                weights[cursor[b as usize]] = w;
                cursor[b as usize] += 1;
            }
        }
        let mut g = Graph {
            offsets,
            targets,
            weights,
            staged: std::collections::BTreeMap::new(),
            n_directed: 0,
            n_self_loops: 0,
        };
        g.sort_and_merge_duplicates();
        g
    }

    /// Sort each adjacency row by target and merge duplicate entries
    /// (summing weights). Keeps CSR canonical for fast binary search.
    fn sort_and_merge_duplicates(&mut self) {
        let n = self.num_nodes();
        let mut new_offsets = vec![0usize; n + 1];
        let mut new_targets = Vec::with_capacity(self.targets.len());
        let mut new_weights = Vec::with_capacity(self.weights.len());
        let mut row: Vec<(u32, f64)> = Vec::new();
        let mut self_loops = 0usize;
        for i in 0..n {
            row.clear();
            let (s, e) = (self.offsets[i], self.offsets[i + 1]);
            for k in s..e {
                row.push((self.targets[k], self.weights[k]));
            }
            row.sort_unstable_by_key(|&(t, _)| t);
            let mut j = 0;
            while j < row.len() {
                let t = row[j].0;
                let mut w = 0.0;
                while j < row.len() && row[j].0 == t {
                    w += row[j].1;
                    j += 1;
                }
                if t as usize == i {
                    self_loops += 1;
                }
                new_targets.push(t);
                new_weights.push(w);
            }
            new_offsets[i + 1] = new_targets.len();
        }
        self.offsets = new_offsets;
        self.targets = new_targets;
        self.weights = new_weights;
        self.n_directed = self.targets.len();
        self.n_self_loops = self_loops;
    }

    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges (self-loops count once). O(1): both
    /// counters are maintained across mutations, so the server stats
    /// path never scans the rows under the model lock.
    pub fn num_edges(&self) -> usize {
        (self.n_directed - self.n_self_loops) / 2 + self.n_self_loops
    }

    /// Adjacency row of node `i`: `(targets, weights)`, sorted by
    /// target. Prefers the staged copy (see the struct docs) so every
    /// reader sees the post-mutation row without a CSR splice.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        if !self.staged.is_empty() {
            if let Some(s) = self.staged.get(&(i as u32)) {
                return (&s.targets, &s.weights);
            }
        }
        let (a, b) = (self.offsets[i], self.offsets[i + 1]);
        (&self.targets[a..b], &self.weights[a..b])
    }

    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        self.row(i).0
    }

    #[inline]
    pub fn neighbor_weights(&self, i: usize) -> &[f64] {
        self.row(i).1
    }

    /// Unweighted degree of node i.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        self.row(i).0.len()
    }

    /// Weighted degree (row sum of W).
    pub fn weighted_degree(&self, i: usize) -> f64 {
        self.neighbor_weights(i).iter().sum()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.num_nodes()).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.num_nodes() == 0 {
            return 0.0;
        }
        self.n_directed as f64 / self.num_nodes() as f64
    }

    pub fn max_edge_weight(&self) -> f64 {
        (0..self.num_nodes())
            .flat_map(|i| self.neighbor_weights(i).iter().cloned())
            .fold(0.0, f64::max)
    }

    /// Dense adjacency matrix (for small-N exact baselines / tests).
    pub fn dense_adjacency(&self) -> Vec<Vec<f64>> {
        let n = self.num_nodes();
        let mut w = vec![vec![0.0; n]; n];
        for i in 0..n {
            for (t, wt) in self.neighbors(i).iter().zip(self.neighbor_weights(i)) {
                w[i][*t as usize] += wt;
            }
        }
        w
    }

    /// Dense graph Laplacian L = D - W.
    pub fn dense_laplacian(&self) -> Vec<Vec<f64>> {
        let mut l = self.dense_adjacency();
        let n = self.num_nodes();
        for (i, row) in l.iter_mut().enumerate().take(n) {
            let d: f64 = row.iter().sum();
            for (j, v) in row.iter_mut().enumerate() {
                *v = if i == j { d - *v } else { -*v };
            }
        }
        l
    }

    /// Scale all edge weights uniformly (used to keep power series in
    /// the GRF convergence radius).
    pub fn scale_weights(&mut self, factor: f64) {
        for w in &mut self.weights {
            *w *= factor;
        }
        for s in self.staged.values_mut() {
            for w in &mut s.weights {
                *w *= factor;
            }
        }
    }

    /// Check structural invariants (CSR sorted, symmetric, staged-row
    /// bookkeeping). Test helper.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_nodes();
        if *self.offsets.last().unwrap() != self.targets.len()
            || self.targets.len() != self.weights.len()
        {
            return Err("offsets/targets/weights inconsistent".into());
        }
        if let Some(&k) = self.staged.keys().next_back() {
            if k as usize >= n {
                return Err(format!("staged row {k} out of range (n={n})"));
            }
        }
        let live: usize = (0..n).map(|i| self.degree(i)).sum();
        if live != self.n_directed {
            return Err(format!(
                "n_directed {} != live entry count {live}",
                self.n_directed
            ));
        }
        let loops = (0..n).filter(|&i| self.has_entry(i, i)).count();
        if loops != self.n_self_loops {
            return Err(format!(
                "n_self_loops {} != live self-loop count {loops}",
                self.n_self_loops
            ));
        }
        for i in 0..n {
            let nb = self.neighbors(i);
            for w in nb.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {i} not strictly sorted"));
                }
            }
            for (t, w) in nb.iter().zip(self.neighbor_weights(i)) {
                let back = self.edge_weight(*t as usize, i);
                if (back - w).abs() > 1e-12 {
                    return Err(format!("asymmetric edge ({i},{t})"));
                }
            }
        }
        Ok(())
    }

    /// Weight of edge (i, j), 0.0 if absent. Binary search (rows sorted).
    pub fn edge_weight(&self, i: usize, j: usize) -> f64 {
        let nb = self.neighbors(i);
        match nb.binary_search(&(j as u32)) {
            Ok(k) => self.neighbor_weights(i)[k],
            Err(_) => 0.0,
        }
    }

    // ------------------------------------------------------------------
    // Mutable ops (streaming / dynamic-graph subsystem)
    // ------------------------------------------------------------------

    /// Append an isolated node; returns its id. O(1).
    pub fn add_node(&mut self) -> usize {
        let n = self.num_nodes();
        // Same u32-id-space guard as `from_edges`: the new node's id
        // must remain representable in CSR targets / staged-row keys.
        assert!(
            u32::try_from(n).map(|i| i < u32::MAX).unwrap_or(false),
            "graph node count {n} exceeds the u32 id space"
        );
        self.offsets.push(*self.offsets.last().unwrap());
        n
    }

    /// Insert `(col, w)` into row `row` keeping the row sorted; if the
    /// entry exists, sum the weight (matching `from_edges` duplicate
    /// merging). A structural insert stages the row (copy-on-write, see
    /// the struct docs) instead of splicing the global CSR: O(deg) per
    /// mutation, not O(N + nnz).
    fn upsert_entry(&mut self, row: usize, col: u32, w: f64) {
        if let Some(s) = self.staged.get_mut(&(row as u32)) {
            match s.targets.binary_search(&col) {
                Ok(k) => s.weights[k] += w,
                Err(k) => {
                    s.targets.insert(k, col);
                    s.weights.insert(k, w);
                    self.n_directed += 1;
                    if row as u32 == col {
                        self.n_self_loops += 1;
                    }
                }
            }
            return;
        }
        let (a, b) = (self.offsets[row], self.offsets[row + 1]);
        match self.targets[a..b].binary_search(&col) {
            // Weight-only reinforcement: structure unchanged, edit the
            // base entry in place (no staging needed).
            Ok(k) => self.weights[a + k] += w,
            Err(k) => {
                let mut s = StagedRow {
                    targets: self.targets[a..b].to_vec(),
                    weights: self.weights[a..b].to_vec(),
                };
                s.targets.insert(k, col);
                s.weights.insert(k, w);
                self.staged.insert(row as u32, s);
                self.n_directed += 1;
                if row as u32 == col {
                    self.n_self_loops += 1;
                }
            }
        }
    }

    /// Remove `(col, _)` from row `row`; returns false if absent.
    /// Structural removals stage the row like [`Graph::upsert_entry`].
    fn remove_entry(&mut self, row: usize, col: u32) -> bool {
        if let Some(s) = self.staged.get_mut(&(row as u32)) {
            return match s.targets.binary_search(&col) {
                Ok(k) => {
                    s.targets.remove(k);
                    s.weights.remove(k);
                    self.n_directed -= 1;
                    if row as u32 == col {
                        self.n_self_loops -= 1;
                    }
                    true
                }
                Err(_) => false,
            };
        }
        let (a, b) = (self.offsets[row], self.offsets[row + 1]);
        match self.targets[a..b].binary_search(&col) {
            Ok(k) => {
                let mut s = StagedRow {
                    targets: self.targets[a..b].to_vec(),
                    weights: self.weights[a..b].to_vec(),
                };
                s.targets.remove(k);
                s.weights.remove(k);
                self.staged.insert(row as u32, s);
                self.n_directed -= 1;
                if row as u32 == col {
                    self.n_self_loops -= 1;
                }
                true
            }
            Err(_) => false,
        }
    }

    /// Number of rows currently held in the staged edge buffer.
    pub fn staged_rows(&self) -> usize {
        self.staged.len()
    }

    /// Fold the staged rows back into one canonical CSR (single O(nnz)
    /// pass) and clear the buffer. The streaming subsystem calls this
    /// alongside its feature-overlay compaction; reads are identical
    /// before and after.
    pub fn compact(&mut self) {
        if self.staged.is_empty() {
            return;
        }
        let n = self.num_nodes();
        let staged = std::mem::take(&mut self.staged);
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        let mut targets = Vec::with_capacity(self.n_directed);
        let mut weights = Vec::with_capacity(self.n_directed);
        for i in 0..n {
            if let Some(s) = staged.get(&(i as u32)) {
                targets.extend_from_slice(&s.targets);
                weights.extend_from_slice(&s.weights);
            } else {
                let (a, b) = (self.offsets[i], self.offsets[i + 1]);
                targets.extend_from_slice(&self.targets[a..b]);
                weights.extend_from_slice(&self.weights[a..b]);
            }
            offsets.push(targets.len());
        }
        self.offsets = offsets;
        self.targets = targets;
        self.weights = weights;
        debug_assert_eq!(self.n_directed, self.targets.len());
    }

    /// Add (or reinforce: weights sum, as in `from_edges`) the
    /// undirected edge (u, v). Self-loops store a single directed
    /// entry. O(deg + log deg) via the staged per-row edge buffer.
    pub fn add_edge(&mut self, u: usize, v: usize, w: f64) {
        let n = self.num_nodes();
        assert!(u < n && v < n, "add_edge out of range");
        self.upsert_entry(u, v as u32, w);
        if u != v {
            self.upsert_entry(v, u as u32, w);
        }
    }

    /// Remove the undirected edge (u, v) entirely (both directions).
    /// Returns false (graph unchanged) if the edge is absent.
    pub fn remove_edge(&mut self, u: usize, v: usize) -> bool {
        let n = self.num_nodes();
        assert!(u < n && v < n, "remove_edge out of range");
        if !self.has_entry(u, v) {
            return false;
        }
        self.remove_entry(u, v as u32);
        if u != v {
            let removed = self.remove_entry(v, u as u32);
            debug_assert!(removed, "asymmetric edge ({u},{v})");
        }
        true
    }

    /// Structural presence of entry (i, j) regardless of weight value.
    fn has_entry(&self, i: usize, j: usize) -> bool {
        self.neighbors(i).binary_search(&(j as u32)).is_ok()
    }

    /// Structural presence of the undirected edge (u, v) — what
    /// [`Graph::remove_edge`] checks before removing. Public so batch
    /// validators can pre-check a delta sequence without mutating.
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.has_entry(u, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (0, 2, 3.0)])
    }

    #[test]
    fn csr_structure() {
        let g = triangle();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.edge_weight(1, 2), 2.0);
        assert_eq!(g.edge_weight(2, 1), 2.0);
        assert_eq!(g.edge_weight(0, 0), 0.0);
        g.validate().unwrap();
    }

    #[test]
    fn duplicate_edges_merge() {
        let g = Graph::from_edges(2, &[(0, 1, 1.0), (0, 1, 0.5), (1, 0, 0.25)]);
        assert_eq!(g.num_edges(), 1);
        assert!((g.edge_weight(0, 1) - 1.75).abs() < 1e-12);
        g.validate().unwrap();
    }

    #[test]
    fn laplacian_rows_sum_to_zero() {
        let g = triangle();
        let l = g.dense_laplacian();
        for row in &l {
            assert!(row.iter().sum::<f64>().abs() < 1e-12);
        }
        // Diagonal = weighted degree.
        assert!((l[0][0] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_degree() {
        let g = triangle();
        assert!((g.weighted_degree(0) - 4.0).abs() < 1e-12);
        assert!((g.avg_degree() - 2.0).abs() < 1e-12);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn mutable_ops_match_from_edges() {
        // Building incrementally must end at the same CSR as the batch
        // constructor over the final edge list.
        let mut g = Graph::from_edges(3, &[(0, 1, 1.0)]);
        let id = g.add_node();
        assert_eq!(id, 3);
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.degree(3), 0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(0, 3, 0.5);
        g.add_edge(0, 1, 0.25); // reinforce: weights sum
        g.validate().unwrap();
        // Structural inserts stage their rows instead of splicing.
        assert!(g.staged_rows() > 0);
        g.compact();
        assert_eq!(g.staged_rows(), 0);
        g.validate().unwrap();
        let want = Graph::from_edges(
            4,
            &[(0, 1, 1.25), (1, 2, 2.0), (0, 3, 0.5)],
        );
        assert_eq!(g.offsets, want.offsets);
        assert_eq!(g.targets, want.targets);
        for (a, b) in g.weights.iter().zip(&want.weights) {
            assert!((a - b).abs() < 1e-12);
        }
        // Removal restores the pre-edge structure.
        assert!(g.remove_edge(0, 3));
        assert!(!g.remove_edge(0, 3));
        assert_eq!(g.degree(3), 0);
        assert_eq!(g.degree(0), 1);
        g.validate().unwrap();
    }

    #[test]
    fn staged_buffer_reads_match_compacted() {
        // Property: after any interleaving of mutations, every accessor
        // answers identically before and after compact(), and the
        // compacted CSR equals the batch constructor on the final edges.
        let mut g = Graph::from_edges(
            6,
            &[(0, 1, 1.0), (1, 2, 0.5), (2, 3, 2.0), (4, 4, 0.25)],
        );
        g.add_edge(0, 5, 0.7);
        g.add_edge(3, 3, 1.5); // staged self-loop
        assert!(g.remove_edge(1, 2));
        g.add_edge(0, 1, 0.5); // weight-only: no staging of row 0's entry
        let before: Vec<(Vec<u32>, Vec<f64>, f64)> = (0..6)
            .map(|i| {
                (
                    g.neighbors(i).to_vec(),
                    g.neighbor_weights(i).to_vec(),
                    g.weighted_degree(i),
                )
            })
            .collect();
        let (ne, avg) = (g.num_edges(), g.avg_degree());
        g.validate().unwrap();
        g.compact();
        g.validate().unwrap();
        for (i, (nb, wt, wd)) in before.iter().enumerate() {
            assert_eq!(g.neighbors(i), &nb[..], "row {i} targets");
            assert_eq!(g.neighbor_weights(i), &wt[..], "row {i} weights");
            assert!((g.weighted_degree(i) - wd).abs() < 1e-12);
        }
        assert_eq!(g.num_edges(), ne);
        assert!((g.avg_degree() - avg).abs() < 1e-12);
        let want = Graph::from_edges(
            6,
            &[(0, 1, 1.5), (2, 3, 2.0), (4, 4, 0.25), (0, 5, 0.7), (3, 3, 1.5)],
        );
        assert_eq!(g.offsets, want.offsets);
        assert_eq!(g.targets, want.targets);
        for (a, b) in g.weights.iter().zip(&want.weights) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn mutable_self_loop_single_entry() {
        let mut g = Graph::from_edges(2, &[(0, 1, 1.0)]);
        g.add_edge(1, 1, 3.0);
        assert_eq!(g.degree(1), 2);
        assert!((g.edge_weight(1, 1) - 3.0).abs() < 1e-12);
        g.validate().unwrap();
        assert!(g.remove_edge(1, 1));
        assert_eq!(g.degree(1), 1);
        g.validate().unwrap();
    }
}
