//! Graph substrate: CSR adjacency, generators, I/O, statistics.

pub mod generators;
pub mod io;
pub mod stats;

/// Undirected weighted graph in CSR (compressed sparse row) form.
///
/// Both directions of every undirected edge are stored, so `neighbors(i)`
/// is a single contiguous slice. Node ids are `u32` (graphs up to ~4B
/// nodes; the paper's largest is 1.13M).
#[derive(Clone, Debug)]
pub struct Graph {
    /// Row pointer, length n+1.
    pub offsets: Vec<usize>,
    /// Column indices (neighbor ids), length 2|E|.
    pub targets: Vec<u32>,
    /// Edge weights, parallel to `targets`.
    pub weights: Vec<f64>,
}

impl Graph {
    /// Build from an undirected edge list. Duplicate edges are summed;
    /// self-loops are kept as single directed entries.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f64)]) -> Graph {
        let mut deg = vec![0usize; n];
        for &(a, b, _) in edges {
            assert!((a as usize) < n && (b as usize) < n, "edge out of range");
            deg[a as usize] += 1;
            if a != b {
                deg[b as usize] += 1;
            }
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let nnz = offsets[n];
        let mut targets = vec![0u32; nnz];
        let mut weights = vec![0f64; nnz];
        let mut cursor = offsets.clone();
        for &(a, b, w) in edges {
            targets[cursor[a as usize]] = b;
            weights[cursor[a as usize]] = w;
            cursor[a as usize] += 1;
            if a != b {
                targets[cursor[b as usize]] = a;
                weights[cursor[b as usize]] = w;
                cursor[b as usize] += 1;
            }
        }
        let mut g = Graph { offsets, targets, weights };
        g.sort_and_merge_duplicates();
        g
    }

    /// Sort each adjacency row by target and merge duplicate entries
    /// (summing weights). Keeps CSR canonical for fast binary search.
    fn sort_and_merge_duplicates(&mut self) {
        let n = self.num_nodes();
        let mut new_offsets = vec![0usize; n + 1];
        let mut new_targets = Vec::with_capacity(self.targets.len());
        let mut new_weights = Vec::with_capacity(self.weights.len());
        let mut row: Vec<(u32, f64)> = Vec::new();
        for i in 0..n {
            row.clear();
            let (s, e) = (self.offsets[i], self.offsets[i + 1]);
            for k in s..e {
                row.push((self.targets[k], self.weights[k]));
            }
            row.sort_unstable_by_key(|&(t, _)| t);
            let mut j = 0;
            while j < row.len() {
                let t = row[j].0;
                let mut w = 0.0;
                while j < row.len() && row[j].0 == t {
                    w += row[j].1;
                    j += 1;
                }
                new_targets.push(t);
                new_weights.push(w);
            }
            new_offsets[i + 1] = new_targets.len();
        }
        self.offsets = new_offsets;
        self.targets = new_targets;
        self.weights = new_weights;
    }

    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges (self-loops count once).
    pub fn num_edges(&self) -> usize {
        let directed = self.targets.len();
        let self_loops = (0..self.num_nodes())
            .map(|i| self.neighbors(i).iter().filter(|&&t| t as usize == i).count())
            .sum::<usize>();
        (directed - self_loops) / 2 + self_loops
    }

    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.targets[self.offsets[i]..self.offsets[i + 1]]
    }

    #[inline]
    pub fn neighbor_weights(&self, i: usize) -> &[f64] {
        &self.weights[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Unweighted degree of node i.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// Weighted degree (row sum of W).
    pub fn weighted_degree(&self, i: usize) -> f64 {
        self.neighbor_weights(i).iter().sum()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.num_nodes()).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.num_nodes() == 0 {
            return 0.0;
        }
        self.targets.len() as f64 / self.num_nodes() as f64
    }

    pub fn max_edge_weight(&self) -> f64 {
        self.weights.iter().cloned().fold(0.0, f64::max)
    }

    /// Dense adjacency matrix (for small-N exact baselines / tests).
    pub fn dense_adjacency(&self) -> Vec<Vec<f64>> {
        let n = self.num_nodes();
        let mut w = vec![vec![0.0; n]; n];
        for i in 0..n {
            for (t, wt) in self.neighbors(i).iter().zip(self.neighbor_weights(i)) {
                w[i][*t as usize] += wt;
            }
        }
        w
    }

    /// Dense graph Laplacian L = D - W.
    pub fn dense_laplacian(&self) -> Vec<Vec<f64>> {
        let mut l = self.dense_adjacency();
        let n = self.num_nodes();
        for (i, row) in l.iter_mut().enumerate().take(n) {
            let d: f64 = row.iter().sum();
            for (j, v) in row.iter_mut().enumerate() {
                *v = if i == j { d - *v } else { -*v };
            }
        }
        l
    }

    /// Scale all edge weights uniformly (used to keep power series in
    /// the GRF convergence radius).
    pub fn scale_weights(&mut self, factor: f64) {
        for w in &mut self.weights {
            *w *= factor;
        }
    }

    /// Check structural invariants (CSR sorted, symmetric). Test helper.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_nodes();
        if *self.offsets.last().unwrap() != self.targets.len()
            || self.targets.len() != self.weights.len()
        {
            return Err("offsets/targets/weights inconsistent".into());
        }
        for i in 0..n {
            let nb = self.neighbors(i);
            for w in nb.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {i} not strictly sorted"));
                }
            }
            for (t, w) in nb.iter().zip(self.neighbor_weights(i)) {
                let back = self.edge_weight(*t as usize, i);
                if (back - w).abs() > 1e-12 {
                    return Err(format!("asymmetric edge ({i},{t})"));
                }
            }
        }
        Ok(())
    }

    /// Weight of edge (i, j), 0.0 if absent. Binary search (rows sorted).
    pub fn edge_weight(&self, i: usize, j: usize) -> f64 {
        let nb = self.neighbors(i);
        match nb.binary_search(&(j as u32)) {
            Ok(k) => self.neighbor_weights(i)[k],
            Err(_) => 0.0,
        }
    }

    // ------------------------------------------------------------------
    // Mutable ops (streaming / dynamic-graph subsystem)
    // ------------------------------------------------------------------

    /// Append an isolated node; returns its id. O(1).
    pub fn add_node(&mut self) -> usize {
        let n = self.num_nodes();
        self.offsets.push(*self.offsets.last().unwrap());
        n
    }

    /// Insert `(col, w)` into row `row` keeping the row sorted; if the
    /// entry exists, sum the weight (matching `from_edges` duplicate
    /// merging). Degree bookkeeping = the offsets shift of rows > row.
    fn upsert_entry(&mut self, row: usize, col: u32, w: f64) {
        let (s, e) = (self.offsets[row], self.offsets[row + 1]);
        match self.targets[s..e].binary_search(&col) {
            Ok(k) => self.weights[s + k] += w,
            Err(k) => {
                self.targets.insert(s + k, col);
                self.weights.insert(s + k, w);
                for o in &mut self.offsets[row + 1..] {
                    *o += 1;
                }
            }
        }
    }

    /// Remove `(col, _)` from row `row`; returns false if absent.
    fn remove_entry(&mut self, row: usize, col: u32) -> bool {
        let (s, e) = (self.offsets[row], self.offsets[row + 1]);
        match self.targets[s..e].binary_search(&col) {
            Ok(k) => {
                self.targets.remove(s + k);
                self.weights.remove(s + k);
                for o in &mut self.offsets[row + 1..] {
                    *o -= 1;
                }
                true
            }
            Err(_) => false,
        }
    }

    /// Add (or reinforce: weights sum, as in `from_edges`) the
    /// undirected edge (u, v). Self-loops store a single directed
    /// entry. O(N + E) worst case for the CSR splice — the cost the
    /// streaming subsystem amortises is the *walk resample*, not this.
    pub fn add_edge(&mut self, u: usize, v: usize, w: f64) {
        let n = self.num_nodes();
        assert!(u < n && v < n, "add_edge out of range");
        self.upsert_entry(u, v as u32, w);
        if u != v {
            self.upsert_entry(v, u as u32, w);
        }
    }

    /// Remove the undirected edge (u, v) entirely (both directions).
    /// Returns false (graph unchanged) if the edge is absent.
    pub fn remove_edge(&mut self, u: usize, v: usize) -> bool {
        let n = self.num_nodes();
        assert!(u < n && v < n, "remove_edge out of range");
        if !self.has_entry(u, v) {
            return false;
        }
        self.remove_entry(u, v as u32);
        if u != v {
            let removed = self.remove_entry(v, u as u32);
            debug_assert!(removed, "asymmetric edge ({u},{v})");
        }
        true
    }

    /// Structural presence of entry (i, j) regardless of weight value.
    fn has_entry(&self, i: usize, j: usize) -> bool {
        self.neighbors(i).binary_search(&(j as u32)).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (0, 2, 3.0)])
    }

    #[test]
    fn csr_structure() {
        let g = triangle();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.edge_weight(1, 2), 2.0);
        assert_eq!(g.edge_weight(2, 1), 2.0);
        assert_eq!(g.edge_weight(0, 0), 0.0);
        g.validate().unwrap();
    }

    #[test]
    fn duplicate_edges_merge() {
        let g = Graph::from_edges(2, &[(0, 1, 1.0), (0, 1, 0.5), (1, 0, 0.25)]);
        assert_eq!(g.num_edges(), 1);
        assert!((g.edge_weight(0, 1) - 1.75).abs() < 1e-12);
        g.validate().unwrap();
    }

    #[test]
    fn laplacian_rows_sum_to_zero() {
        let g = triangle();
        let l = g.dense_laplacian();
        for row in &l {
            assert!(row.iter().sum::<f64>().abs() < 1e-12);
        }
        // Diagonal = weighted degree.
        assert!((l[0][0] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_degree() {
        let g = triangle();
        assert!((g.weighted_degree(0) - 4.0).abs() < 1e-12);
        assert!((g.avg_degree() - 2.0).abs() < 1e-12);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn mutable_ops_match_from_edges() {
        // Building incrementally must end at the same CSR as the batch
        // constructor over the final edge list.
        let mut g = Graph::from_edges(3, &[(0, 1, 1.0)]);
        let id = g.add_node();
        assert_eq!(id, 3);
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.degree(3), 0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(0, 3, 0.5);
        g.add_edge(0, 1, 0.25); // reinforce: weights sum
        g.validate().unwrap();
        let want = Graph::from_edges(
            4,
            &[(0, 1, 1.25), (1, 2, 2.0), (0, 3, 0.5)],
        );
        assert_eq!(g.offsets, want.offsets);
        assert_eq!(g.targets, want.targets);
        for (a, b) in g.weights.iter().zip(&want.weights) {
            assert!((a - b).abs() < 1e-12);
        }
        // Removal restores the pre-edge structure.
        assert!(g.remove_edge(0, 3));
        assert!(!g.remove_edge(0, 3));
        assert_eq!(g.degree(3), 0);
        assert_eq!(g.degree(0), 1);
        g.validate().unwrap();
    }

    #[test]
    fn mutable_self_loop_single_entry() {
        let mut g = Graph::from_edges(2, &[(0, 1, 1.0)]);
        g.add_edge(1, 1, 3.0);
        assert_eq!(g.degree(1), 2);
        assert!((g.edge_weight(1, 1) - 3.0).abs() < 1e-12);
        g.validate().unwrap();
        assert!(g.remove_edge(1, 1));
        assert_eq!(g.degree(1), 1);
        g.validate().unwrap();
    }
}
