//! Graph serialization: a simple whitespace edge-list format
//! (`src dst [weight]` per line, `#` comments) compatible with SNAP
//! exports, plus save/load helpers.

use super::Graph;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Parse an edge-list text. Node ids are remapped densely in first-seen
/// order if `remap` is true, otherwise they must be < `n_hint`.
pub fn parse_edge_list(text: &str, remap: bool) -> Result<Graph> {
    let mut edges: Vec<(u32, u32, f64)> = Vec::new();
    let mut map = std::collections::HashMap::new();
    let mut max_id = 0u32;
    let intern = |raw: u64, map: &mut std::collections::HashMap<u64, u32>, max_id: &mut u32| -> u32 {
        if remap {
            let next = map.len() as u32;
            let id = *map.entry(raw).or_insert(next);
            *max_id = (*max_id).max(id);
            id
        } else {
            let id = raw as u32;
            *max_id = (*max_id).max(id);
            id
        }
    };
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let a: u64 = parts
            .next()
            .context("missing src")?
            .parse()
            .with_context(|| format!("line {}: bad src", ln + 1))?;
        let b: u64 = parts
            .next()
            .with_context(|| format!("line {}: missing dst", ln + 1))?
            .parse()
            .with_context(|| format!("line {}: bad dst", ln + 1))?;
        let w: f64 = match parts.next() {
            Some(t) => t
                .parse()
                .with_context(|| format!("line {}: bad weight", ln + 1))?,
            None => 1.0,
        };
        if !w.is_finite() || w < 0.0 {
            bail!("line {}: weight must be finite and >= 0", ln + 1);
        }
        let ai = intern(a, &mut map, &mut max_id);
        let bi = intern(b, &mut map, &mut max_id);
        edges.push((ai, bi, w));
    }
    if edges.is_empty() {
        bail!("no edges found");
    }
    Ok(Graph::from_edges(max_id as usize + 1, &edges))
}

pub fn load_edge_list(path: &Path) -> Result<Graph> {
    load_edge_list_opts(path, true)
}

/// `remap=false` preserves numeric node ids (files we wrote ourselves);
/// `remap=true` renumbers densely in first-seen order (raw SNAP dumps).
pub fn load_edge_list_opts(path: &Path, remap: bool) -> Result<Graph> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut text = String::new();
    for line in std::io::BufReader::new(file).lines() {
        text.push_str(&line?);
        text.push('\n');
    }
    parse_edge_list(&text, remap)
}

pub fn save_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# nodes {} edges {}", g.num_nodes(), g.num_edges())?;
    for i in 0..g.num_nodes() {
        for (t, wt) in g.neighbors(i).iter().zip(g.neighbor_weights(i)) {
            if i <= *t as usize {
                writeln!(w, "{} {} {}", i, t, wt)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn parse_basic() {
        let g = parse_edge_list("# comment\n0 1\n1 2 0.5\n", false).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.edge_weight(1, 2), 0.5);
    }

    #[test]
    fn remap_sparse_ids() {
        let g = parse_edge_list("100 200\n200 300\n", true).unwrap();
        assert_eq!(g.num_nodes(), 3);
    }

    #[test]
    fn save_load_roundtrip() {
        let g = generators::grid2d(4, 4);
        let path = std::env::temp_dir().join("grfgp_io_test.edges");
        save_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list_opts(&path, false).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.num_edges(), g2.num_edges());
        for i in 0..g.num_nodes() {
            assert_eq!(g.neighbors(i), g2.neighbors(i));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_edge_list("", false).is_err());
        assert!(parse_edge_list("0 x\n", false).is_err());
        assert!(parse_edge_list("0 1 -2\n", false).is_err());
    }
}
