//! Graph statistics: connected components, BFS distances, diameter
//! estimates, degree summaries.

use super::Graph;
use crate::util::rng::Rng;

/// BFS distances from `source` (usize::MAX = unreachable).
pub fn bfs_distances(g: &Graph, source: usize) -> Vec<usize> {
    let n = g.num_nodes();
    let mut dist = vec![usize::MAX; n];
    let mut queue = std::collections::VecDeque::new();
    dist[source] = 0;
    queue.push_back(source);
    while let Some(u) = queue.pop_front() {
        for &v in g.neighbors(u) {
            let v = v as usize;
            if dist[v] == usize::MAX {
                dist[v] = dist[u] + 1;
                queue.push_back(v);
            }
        }
    }
    dist
}

/// Connected-component labels (BFS flood fill).
pub fn components(g: &Graph) -> Vec<usize> {
    let n = g.num_nodes();
    let mut comp = vec![usize::MAX; n];
    let mut next = 0;
    for s in 0..n {
        if comp[s] != usize::MAX {
            continue;
        }
        let mut queue = std::collections::VecDeque::new();
        comp[s] = next;
        queue.push_back(s);
        while let Some(u) = queue.pop_front() {
            for &v in g.neighbors(u) {
                let v = v as usize;
                if comp[v] == usize::MAX {
                    comp[v] = next;
                    queue.push_back(v);
                }
            }
        }
        next += 1;
    }
    comp
}

/// Extract the largest connected component. Returns the subgraph and
/// the original ids of the kept nodes (new id i ↔ old id keep[i]).
pub fn largest_component(g: &Graph) -> (Graph, Vec<usize>) {
    let comp = components(g);
    let n = g.num_nodes();
    let ncomp = comp.iter().max().map(|&c| c + 1).unwrap_or(0);
    let mut sizes = vec![0usize; ncomp];
    for &c in &comp {
        sizes[c] += 1;
    }
    let best = sizes
        .iter()
        .enumerate()
        .max_by_key(|&(_, &s)| s)
        .map(|(c, _)| c)
        .unwrap_or(0);
    let keep: Vec<usize> = (0..n).filter(|&i| comp[i] == best).collect();
    let mut new_id = vec![u32::MAX; n];
    for (ni, &oi) in keep.iter().enumerate() {
        new_id[oi] = ni as u32;
    }
    let mut edges = Vec::new();
    for &oi in &keep {
        for (t, w) in g.neighbors(oi).iter().zip(g.neighbor_weights(oi)) {
            let tj = *t as usize;
            if comp[tj] == best && oi <= tj {
                edges.push((new_id[oi], new_id[tj], *w));
            }
        }
    }
    (Graph::from_edges(keep.len(), &edges), keep)
}

/// Lower-bound diameter estimate via double-sweep BFS from `probes`
/// random sources.
pub fn diameter_estimate(g: &Graph, probes: usize, rng: &mut Rng) -> usize {
    let n = g.num_nodes();
    let mut best = 0;
    for _ in 0..probes {
        let s = rng.below(n);
        let d1 = bfs_distances(g, s);
        let (far, d) = d1
            .iter()
            .enumerate()
            .filter(|(_, &d)| d != usize::MAX)
            .max_by_key(|(_, &d)| d)
            .unwrap();
        best = best.max(*d);
        let d2 = bfs_distances(g, far);
        let m = d2.iter().filter(|&&d| d != usize::MAX).max().unwrap();
        best = best.max(*m);
    }
    best
}

/// Degree summary (min, mean, max).
pub fn degree_summary(g: &Graph) -> (usize, f64, usize) {
    let n = g.num_nodes();
    let mut min = usize::MAX;
    let mut max = 0;
    let mut sum = 0usize;
    for i in 0..n {
        let d = g.degree(i);
        min = min.min(d);
        max = max.max(d);
        sum += d;
    }
    (min, sum as f64 / n as f64, max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn bfs_on_ring() {
        let g = generators::ring(8);
        let d = bfs_distances(&g, 0);
        assert_eq!(d, vec![0, 1, 2, 3, 4, 3, 2, 1]);
    }

    #[test]
    fn components_split() {
        // Two triangles, disconnected.
        let g = Graph::from_edges(
            6,
            &[(0, 1, 1.0), (1, 2, 1.0), (0, 2, 1.0),
              (3, 4, 1.0), (4, 5, 1.0), (3, 5, 1.0)],
        );
        let c = components(&g);
        assert_eq!(c[0], c[1]);
        assert_eq!(c[3], c[4]);
        assert_ne!(c[0], c[3]);
        let (lc, keep) = largest_component(&g);
        assert_eq!(lc.num_nodes(), 3);
        assert_eq!(keep.len(), 3);
        lc.validate().unwrap();
    }

    #[test]
    fn diameter_of_ring() {
        let g = generators::ring(10);
        let mut rng = Rng::new(0);
        let d = diameter_estimate(&g, 3, &mut rng);
        assert_eq!(d, 5);
    }

    #[test]
    fn degree_summary_grid() {
        let g = generators::grid2d(3, 3);
        let (min, avg, max) = degree_summary(&g);
        assert_eq!(min, 2);
        assert_eq!(max, 4);
        assert!(avg > 2.0 && avg < 4.0);
    }
}
