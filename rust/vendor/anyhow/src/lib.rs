//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The container image has no network and no crates.io mirror, so the
//! workspace vendors the exact subset of the `anyhow` API that grfgp
//! uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros, and
//! the [`Context`] extension trait over `Result` and `Option`. Errors
//! are flat formatted strings with a context chain — no backtraces, no
//! downcasting.

use std::fmt;

/// String-backed error with an outer-to-inner context chain.
pub struct Error {
    /// Most recent context first, root cause last (like anyhow's Display
    /// of `{:#}`); plain Display shows the outermost entry.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints errors via Debug; keep the
        // readable chained form there too.
        write!(f, "{}", self.chain.join(": "))
    }
}

// NOTE: `Error` deliberately does not implement `std::error::Error`;
// that keeps the blanket `From` below from colliding with the identity
// `From<T> for T` impl (same trick as upstream anyhow).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to the error arm of a `Result` or to `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s
            .parse()
            .with_context(|| format!("bad integer {s:?}"))?;
        if v < 0 {
            bail!("negative: {v}");
        }
        Ok(v)
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = parse("x").unwrap_err();
        let msg = e.to_string();
        assert!(msg.starts_with("bad integer \"x\""), "{msg}");
        assert!(msg.contains(':'), "{msg}");
    }

    #[test]
    fn bail_and_anyhow_format() {
        assert!(parse("7").is_ok());
        let e = parse("-3").unwrap_err();
        assert_eq!(e.to_string(), "negative: -3");
        let e2: Error = anyhow!("code {}", 42);
        assert_eq!(e2.to_string(), "code 42");
    }

    #[test]
    fn io_error_converts_via_question_mark() {
        fn open() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(open().is_err());
        fn opt() -> Result<u32> {
            let v = [1u32, 2].iter().copied().find(|&x| x > 5).context("missing")?;
            Ok(v)
        }
        assert_eq!(opt().unwrap_err().to_string(), "missing");
    }
}
