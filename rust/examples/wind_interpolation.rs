//! Wind interpolation on the globe (paper §4.2.2, Fig. 3c-d, Figs 7-10):
//! implicit manifold GP regression via a kNN graph on S².
//!
//!     cargo run --release --example wind_interpolation -- [res_deg] [walks]
//!
//! res_deg 2.5 reproduces the paper's 10,368-node graph; the default 5.0
//! (2,592 nodes) runs in seconds.

use grfgp::datasets::wind::{self, Altitude};
use grfgp::gp::metrics::{nlpd, rmse};
use grfgp::gp::{GpModel, Hypers, Modulation};
use grfgp::util::rng::Rng;
use grfgp::walks::{sample_components, WalkConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let res: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(5.0);
    let walks: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(256);

    for alt in [Altitude::Low, Altitude::Mid, Altitude::High] {
        let mut rng = Rng::new(3);
        let data = wind::generate(alt, res, &mut rng);
        let cfg = WalkConfig { n_walks: walks, p_halt: 0.1, max_len: 8, ..Default::default() };
        let comps = sample_components(&data.graph, &cfg, 11);
        let mut model = GpModel::new(
            comps,
            Hypers::new(Modulation::learnable_init(8, &mut rng), 0.1),
            &data.train_nodes,
            &data.train_y,
        );
        model.fit(40, 0.02, &mut rng);
        let (mean, var) = model.predict(32, &mut rng);
        let mu: Vec<f64> = data.test_nodes.iter().map(|&i| mean[i]).collect();
        let vv: Vec<f64> = data.test_nodes.iter().map(|&i| var[i]).collect();
        println!(
            "altitude {:>5}: {} nodes, {} track-train nodes  RMSE {:.3}  NLPD {:.3}",
            alt.label(),
            data.graph.num_nodes(),
            data.train_nodes.len(),
            rmse(&mu, &data.test_y),
            nlpd(&mu, &vv, &data.test_y)
        );
    }
}
