//! Quickstart: the full GRF-GP workflow on a small graph in ~40 lines.
//!
//!     cargo run --release --example quickstart
//!
//! 1. Build a graph, 2. sample GRF walk components (kernel init, O(N)),
//! 3. train the kernel + noise hyperparameters by maximising the log
//! marginal likelihood with CG + Hutchinson gradients, 4. predict with
//! pathwise-conditioning samples.

use grfgp::gp::metrics::{nlpd, rmse};
use grfgp::gp::{GpModel, Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::util::rng::Rng;
use grfgp::walks::{sample_components, WalkConfig};

fn main() {
    let mut rng = Rng::new(0);

    // A 30x30 mesh with a smooth ground-truth signal.
    let g = generators::grid2d(30, 30);
    let n = g.num_nodes();
    let truth: Vec<f64> = (0..n)
        .map(|i| {
            let (r, c) = ((i / 30) as f64 / 30.0, (i % 30) as f64 / 30.0);
            (std::f64::consts::TAU * r).sin() + (std::f64::consts::TAU * c).cos()
        })
        .collect();

    // Observe 15% of nodes with noise.
    let train = rng.sample_without_replacement(n, n * 15 / 100);
    let y: Vec<f64> = train.iter().map(|&i| truth[i] + 0.1 * rng.normal()).collect();
    let test: Vec<usize> = (0..n).filter(|i| !train.contains(i)).collect();

    // Kernel initialisation: sample random-walk components once.
    let cfg = WalkConfig { n_walks: 200, p_halt: 0.1, max_len: 6, ..Default::default() };
    let comps = sample_components(&g, &cfg, 42);
    println!(
        "GRF components: {} lengths, {} nonzeros ({} bytes)",
        comps.n_coeffs(),
        comps.nnz(),
        comps.memory_bytes()
    );

    // A GP with a fully-learnable modulation function.
    let hypers = Hypers::new(Modulation::learnable_init(6, &mut rng), 0.1);
    let mut model = GpModel::new(comps, hypers, &train, &y);

    // Hyperparameter learning (paper §3.2): Adam on the stochastic LML.
    let log = model.fit(80, 0.02, &mut rng);
    println!(
        "trained 80 steps: grad_norm {:.4} -> {:.4}, sigma_n^2 = {:.4}",
        log.first().unwrap().grad_norm,
        log.last().unwrap().grad_norm,
        model.hypers.sigma_n2()
    );

    // Posterior inference via pathwise conditioning.
    let (mean, var) = model.predict(32, &mut rng);
    let mu: Vec<f64> = test.iter().map(|&i| mean[i]).collect();
    let vv: Vec<f64> = test.iter().map(|&i| var[i]).collect();
    let yt: Vec<f64> = test.iter().map(|&i| truth[i]).collect();
    println!("test RMSE = {:.3}", rmse(&mu, &yt));
    println!("test NLPD = {:.3}", nlpd(&mu, &vv, &yt));
}
