//! Traffic-speed regression (paper §4.2.1, Fig. 3a-b / Fig. 6) on the
//! San-Jose-substitute road network: exact diffusion kernel vs
//! diffusion-shape GRF vs fully-learnable GRF.
//!
//!     cargo run --release --example traffic_regression -- [walks] [iters]

use grfgp::datasets::traffic;
use grfgp::gp::metrics::{nlpd, rmse};
use grfgp::gp::{ExactGp, ExactKernel, GpModel, Hypers, Modulation};
use grfgp::util::rng::Rng;
use grfgp::walks::{sample_components, WalkConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_walks: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let iters: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(80);

    let mut rng = Rng::new(0);
    let data = traffic::generate(&mut rng);
    println!(
        "road network: {} nodes / {} edges, {} train / {} test sensors",
        data.graph.num_nodes(),
        data.graph.num_edges(),
        data.train_nodes.len(),
        data.test_nodes.len()
    );

    // Exact diffusion baseline (O(N^3) eigendecomposition, feasible at
    // ~1K nodes — exactly the paper's point).
    let mut exact = ExactGp::new(&data.graph, ExactKernel::Diffusion);
    exact.set_data(&data.train_nodes, &data.train_y);
    exact.fit(3).expect("exact fit");
    let (r, nl) = exact.evaluate(&data.test_nodes, &data.test_y).unwrap();
    println!("exact diffusion:      RMSE {r:.3}  NLPD {nl:.3}");

    // GRF kernels.
    for (label, learnable) in [("diffusion-shape GRF", false), ("learnable GRF", true)] {
        let cfg = WalkConfig {
            n_walks,
            p_halt: 0.1,
            max_len: 10,
            ..Default::default()
        };
        let comps = sample_components(&data.graph, &cfg, 7);
        let modulation = if learnable {
            Modulation::learnable_init(10, &mut rng)
        } else {
            Modulation::diffusion(1.0, 1.0, 10)
        };
        let mut model = GpModel::new(
            comps,
            Hypers::new(modulation, 0.1),
            &data.train_nodes,
            &data.train_y,
        );
        model.fit(iters, 0.02, &mut rng);
        let (mean, var) = model.predict(32, &mut rng);
        let mu: Vec<f64> = data.test_nodes.iter().map(|&i| mean[i]).collect();
        let vv: Vec<f64> = data.test_nodes.iter().map(|&i| var[i]).collect();
        println!(
            "{label:<21} RMSE {:.3}  NLPD {:.3}   (n={n_walks} walks)",
            rmse(&mu, &data.test_y),
            nlpd(&mu, &vv, &data.test_y)
        );
    }
}
