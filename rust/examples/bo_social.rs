//! Large-scale Bayesian optimisation (paper §4.3, Fig. 4e-h): find the
//! most "influential" (highest-degree) user in a social network with
//! graph Thompson sampling vs random/BFS/DFS baselines.
//!
//!     cargo run --release --example bo_social -- [scale] [steps]
//!
//! scale 1.0 reproduces the paper's full network sizes (YouTube = 1.13M
//! nodes); the default 0.02 runs in seconds.

use grfgp::bo::{run_policy, BfsPolicy, BoConfig, DfsPolicy, RandomPolicy, ThompsonPolicy};
use grfgp::datasets::social;
use grfgp::util::rng::Rng;
use grfgp::walks::WalkConfig;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let scale: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.02);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(200);

    let mut rng = Rng::new(0);
    let net = social::Network::Enron;
    let g = social::generate(net, scale, &mut rng);
    let (values, optimum) = social::degree_objective(&g);
    println!(
        "{} substitute at scale {scale}: {} nodes / {} edges, max degree {}",
        net.label(),
        g.num_nodes(),
        g.num_edges(),
        optimum
    );

    let cfg = BoConfig {
        n_init: 50,
        n_steps: steps,
        noise: 0.1,
        walk: WalkConfig { n_walks: 100, p_halt: 0.1, max_len: 5, ..Default::default() },
        ..Default::default()
    };
    let h = |i: usize| values[i];
    let n = g.num_nodes();

    let mut rng_run = Rng::new(1);
    let mut ts = ThompsonPolicy::new(&g, &cfg, &mut rng_run);
    let run = run_policy(&mut ts, &h, optimum, n, &cfg, &mut rng_run);
    println!("grf-thompson: final regret {:.1}", run.regret.last().unwrap());

    let mut rng_run = Rng::new(1);
    let mut rp = RandomPolicy::new(n);
    let run = run_policy(&mut rp, &h, optimum, n, &cfg, &mut rng_run);
    println!("random:       final regret {:.1}", run.regret.last().unwrap());

    let mut rng_run = Rng::new(1);
    let mut bp = BfsPolicy::new(&g);
    let run = run_policy(&mut bp, &h, optimum, n, &cfg, &mut rng_run);
    println!("bfs:          final regret {:.1}", run.regret.last().unwrap());

    let mut rng_run = Rng::new(1);
    let mut dp = DfsPolicy::new(&g);
    let run = run_policy(&mut dp, &h, optimum, n, &cfg, &mut rng_run);
    println!("dfs:          final regret {:.1}", run.regret.last().unwrap());
}
