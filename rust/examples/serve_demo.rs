//! End-to-end server demo: starts the GP inference server on a ring
//! graph, then drives it as a client — observations, batched predicts,
//! live graph mutations, Thompson steps — and reports
//! latency/throughput.
//!
//!     cargo run --release --example serve_demo -- [n_nodes] [n_requests]

use grfgp::gp::{Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::stream::StreamingFeatures;
use grfgp::util::rng::Rng;
use grfgp::walks::WalkConfig;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, body: &str) -> String {
    stream.write_all(body.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4096);
    let n_requests: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(50);

    // Build the streaming feature state + hyperparameters.
    let g = generators::ring(n);
    let cfg = WalkConfig { n_walks: 100, p_halt: 0.1, max_len: 5, ..Default::default() };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 5), 0.1);
    let stream = StreamingFeatures::new(g, cfg, hypers.modulation.coeffs(), 0);

    // Serve on an ephemeral port in a background thread.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        grfgp::server::ServeOptions::new()
            .serve_on(stream, hypers, listener)
            .unwrap();
    });

    // Client.
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut rng = Rng::new(1);

    // Seed observations.
    for _ in 0..20 {
        let node = rng.below(n);
        let t = node as f64 / n as f64 * std::f64::consts::TAU;
        let y = t.sin() + 0.1 * rng.normal();
        let resp = request(
            &mut stream,
            &mut reader,
            &format!(r#"{{"op":"observe","node":{node},"y":{y}}}"#),
        );
        assert!(resp.contains("\"ok\":true"), "{resp}");
    }

    // Timed predict requests.
    let t0 = Instant::now();
    for i in 0..n_requests {
        let node = (i * 37) % n;
        let resp = request(
            &mut stream,
            &mut reader,
            &format!(r#"{{"op":"predict","nodes":[{node}],"samples":8}}"#),
        );
        assert!(resp.contains("\"ok\":true"), "{resp}");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "{n_requests} predict requests on N={n}: {:.1} ms/request, {:.1} req/s",
        1e3 * elapsed / n_requests as f64,
        n_requests as f64 / elapsed
    );

    // Live graph mutations: each add_edge resamples only the walks
    // that visited its endpoints and warm-starts the re-solve.
    let t0 = Instant::now();
    for i in 0..5 {
        let (u, v) = (i * 11 % n, (i * 11 + n / 2) % n);
        let resp = request(
            &mut stream,
            &mut reader,
            &format!(r#"{{"op":"add_edge","u":{u},"v":{v},"w":0.5}}"#),
        );
        assert!(resp.contains("\"ok\":true"), "{resp}");
        println!("add_edge({u},{v}) -> {}", resp.trim());
    }
    println!(
        "5 incremental graph deltas on N={n}: {:.1} ms/delta",
        1e3 * t0.elapsed().as_secs_f64() / 5.0
    );

    // A few Thompson steps.
    for _ in 0..3 {
        let resp = request(&mut stream, &mut reader, r#"{"op":"thompson"}"#);
        println!("thompson -> {}", resp.trim());
    }
    let stats = request(&mut stream, &mut reader, r#"{"op":"stats"}"#);
    println!("stats -> {}", stats.trim());

    request(&mut stream, &mut reader, r#"{"op":"shutdown"}"#);
    drop(stream);
    server.join().unwrap();
}
