//! Tentpole property suite: the sharded engine is **bitwise invisible**.
//!
//! For every shard count (default S ∈ {2, 4, 7}; override with
//! `GRFGP_TEST_SHARDS=1,2,4`), a server over the partitioned
//! [`grfgp::shard::ShardedFeatures`] engine must serve predictions,
//! Φ/Φᵀ operands, and `graph_version` stamps **bit-identical** to the
//! mono engine under an identical request script — with the hub cap
//! active and compactions forced mid-run, and with predicts still
//! acquiring zero model locks.
//!
//! What is deliberately NOT compared: per-delta `resampled_walks` /
//! `compacted` ack fields and compaction counts. Per-shard visit
//! indices saturate their hub caps on different cadences than the mono
//! index, so the resample sets (both supersets of the true visitor
//! sets) and overlay occupancies legitimately drift — the features do
//! not. See the `grfgp::shard` module docs.

use grfgp::gp::{Hypers, Modulation};
use grfgp::graph::{generators, Graph};
use grfgp::server::batcher::Request;
use grfgp::server::{handle, ModelState, ServerConfig, ServerState};
use grfgp::stream::StreamingFeatures;
use grfgp::util::rng::Rng;
use grfgp::walks::{Termination, WalkConfig};
use std::sync::atomic::Ordering;

/// Shard counts under test: `GRFGP_TEST_SHARDS` (comma-separated) or
/// the default {2, 4, 7} — coprime, even, and larger-than-typical
/// splits of the node count.
fn shard_counts() -> Vec<usize> {
    match std::env::var("GRFGP_TEST_SHARDS") {
        Ok(spec) => spec
            .split(',')
            .map(|t| t.trim())
            .filter(|t| !t.is_empty())
            .map(|t| {
                t.parse()
                    .unwrap_or_else(|_| panic!("GRFGP_TEST_SHARDS: bad entry {t:?}"))
            })
            .collect(),
        Err(_) => vec![2, 4, 7],
    }
}

/// The graph every state in this suite serves (fixed seed, so the
/// mono and sharded runs — and the script's edge picks — agree).
fn test_graph() -> Graph {
    generators::barabasi_albert(96, 3, &mut Rng::new(5))
}

/// Deterministically pick `k` node pairs that are NOT edges of `g`
/// (so the script's `add_edge`s are guaranteed valid without
/// hard-coding pairs against a generator's output).
fn pick_non_edges(g: &Graph, k: usize) -> Vec<(usize, usize)> {
    let n = g.num_nodes();
    let mut out: Vec<(usize, usize)> = Vec::new();
    'outer: for u in 1..n {
        for v in ((u + 20)..n).step_by(17) {
            let adjacent = g.neighbors(u).iter().any(|&x| x as usize == v);
            let fresh = !out.iter().any(|&(a, b)| (a, b) == (u, v));
            if !adjacent && fresh {
                out.push((u, v));
                if out.len() == k {
                    break 'outer;
                }
                break;
            }
        }
    }
    assert_eq!(out.len(), k, "graph too dense to pick {k} test non-edges");
    out
}

/// A server state over a scale-free graph, with the hub cap low enough
/// to saturate on the BA hubs and the compaction threshold low enough
/// that the delta script folds the overlays mid-run.
fn build_state(n_shards: usize, termination: Termination) -> ServerState {
    let g = test_graph();
    let cfg = WalkConfig {
        n_walks: 12,
        p_halt: 0.15,
        max_len: 3,
        threads: 1,
        termination,
        ..Default::default()
    };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
    let stream = StreamingFeatures::new(g, cfg, hypers.modulation.coeffs(), 0);
    let mut ms = ModelState::new_sharded(stream, hypers, 7, n_shards);
    ms.stream.set_hub_cap(4); // saturates on BA hubs
    ms.stream.set_compact_threshold(2); // folds mid-script
    ServerState::new(ms, ServerConfig::default())
}

/// Drive one fixed write/read script through the full serving path
/// (`handle` → write batches → snapshot publication → wait-free
/// predicts). Returns every predict response rendered to JSON —
/// mean, var, `graph_version` stamp, and `rng_seq` included, so a
/// string comparison between two runs is a bitwise comparison of
/// everything a client can observe from reads.
fn run_script(state: &ServerState, edges: &[(usize, usize)]) -> Vec<String> {
    let mut predicts = Vec::new();
    let mut predict = |nodes: Vec<usize>| {
        let r = handle(state, &Request::Predict { nodes, samples: 3 });
        assert!(r.ok, "{r:?}");
        predicts.push(r.to_json().to_string());
    };
    let mut version = 0u64;
    let mut delta = |req: Request| {
        let r = handle(state, &req);
        assert!(r.ok, "{req:?}: {r:?}");
        version += 1;
        assert_eq!(
            r.to_json().get("graph_version").and_then(|v| v.as_usize()),
            Some(version as usize),
            "delta ack version out of sequence"
        );
    };

    for i in 0..6usize {
        let r = handle(
            state,
            &Request::Observe { node: (i * 13) % 96, y: (i as f64 * 0.7).sin() },
        );
        assert!(r.ok, "{r:?}");
    }
    predict(vec![0, 17, 42]);

    // Edge insertions (guaranteed non-edges picked off the real
    // graph), growth, and removal — each delta batch crosses the
    // forced compaction threshold, and the fan-out invalidates walks
    // across shard boundaries for every S under test.
    assert_eq!(edges.len(), 3, "script wants exactly 3 picked edges");
    let (u0, v0) = edges[0];
    let (u1, v1) = edges[1];
    let (u2, v2) = edges[2];
    delta(Request::AddEdge { u: u0, v: v0, w: 0.9 });
    predict(vec![u0, v0, 93]);
    delta(Request::AddNode);
    let r = handle(state, &Request::Observe { node: 96, y: 0.25 });
    assert!(r.ok, "{r:?}");
    predict(vec![96, 3, 71]);
    delta(Request::AddEdge { u: 96, v: 7, w: 1.2 });
    predict(vec![96, 7]);
    delta(Request::RemoveEdge { u: u0, v: v0 });
    delta(Request::AddEdge { u: u1, v: v1, w: 0.4 });
    delta(Request::AddEdge { u: u2, v: v2, w: 1.1 });
    predict(vec![0, u1, v2, 96]);

    // Wait-free contract, extended to the sharded path: a block of
    // predicts moves the model-lock counter by exactly zero.
    let before = state.model_lock_acquisitions.load(Ordering::SeqCst);
    for k in 0..4usize {
        predict(vec![k * 11, k * 7 + 1]);
    }
    let after = state.model_lock_acquisitions.load(Ordering::SeqCst);
    assert_eq!(
        before, after,
        "a predict acquired the model mutex with {} shard(s)",
        state.snapshots.load().shards
    );
    predicts
}

#[test]
fn sharded_serving_is_bitwise_identical_to_mono() {
    // Scheme × shard-count matrix: the bitwise contract must hold for
    // every walk-termination scheme (`GRFGP_TEST_TERMINATION` narrows
    // the scheme list, like `GRFGP_TEST_SHARDS` for shard counts).
    let edges = pick_non_edges(&test_graph(), 3);
    for scheme in Termination::test_matrix() {
        let mono = build_state(1, scheme);
        let mono_predicts = run_script(&mono, &edges);
        let mono_guard = mono.model_guard();
        let (mono_phi, mono_phi_t) =
            (mono_guard.model.phi_csr(), mono_guard.model.phi_t_csr());
        drop(mono_guard);

        for s in shard_counts() {
            let sharded = build_state(s, scheme);
            assert_eq!(
                sharded.snapshots.load().shards,
                s,
                "snapshot does not expose the composed shard count"
            );
            let got = run_script(&sharded, &edges);
            assert_eq!(
                got.len(),
                mono_predicts.len(),
                "{scheme:?} S={s}: script served a different number of predicts"
            );
            for (k, (a, b)) in mono_predicts.iter().zip(&got).enumerate() {
                assert_eq!(
                    a, b,
                    "{scheme:?} S={s}: predict {k} is not bitwise the mono response"
                );
            }
            let guard = sharded.model_guard();
            assert_eq!(
                guard.model.phi_csr(),
                mono_phi,
                "{scheme:?} S={s}: composed Φ differs from the mono operand"
            );
            assert_eq!(
                guard.model.phi_t_csr(),
                mono_phi_t,
                "{scheme:?} S={s}: composed Φᵀ differs from the mono operand"
            );
            assert_eq!(
                guard.model.partition().map(|p| p.n_shards()),
                if s > 1 { Some(s) } else { None },
                "{scheme:?} S={s}: model operands not stored under the engine partition"
            );
        }
    }
}
