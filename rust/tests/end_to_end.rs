//! End-to-end integration: the experiment drivers themselves, run at
//! smoke scale. These prove the whole system composes — datasets →
//! walk engine → GP training → inference/BO/classification → metrics →
//! result files.

use grfgp::util::cli::Args;

fn args(tokens: &[&str]) -> Args {
    Args::parse(tokens.iter().map(|s| s.to_string()))
}

#[test]
fn scaling_driver_produces_fits() {
    let json = grfgp::exp::scaling::run(&args(&[
        "exp",
        "--sparse-pows",
        "5,6,7,8",
        "--dense-pows",
        "5,6,7",
        "--seeds",
        "1",
        "--train-steps",
        "3",
    ]));
    let fits = json.get("fits").unwrap().as_arr().unwrap();
    assert!(!fits.is_empty());
    // Sparse memory must scale ~linearly even at smoke scale.
    let mem_fit = fits
        .iter()
        .find(|f| {
            f.get("variant").unwrap().as_str() == Some("sparse")
                && f.get("quantity").unwrap().as_str() == Some("Memory (MB)")
        })
        .unwrap();
    let b = mem_fit.get("b").unwrap().as_f64().unwrap();
    assert!((b - 1.0).abs() < 0.25, "sparse memory exponent {b}");
    // Dense memory must scale ~quadratically.
    let dense_mem = fits
        .iter()
        .find(|f| {
            f.get("variant").unwrap().as_str() == Some("dense")
                && f.get("quantity").unwrap().as_str() == Some("Memory (MB)")
        })
        .unwrap();
    let bd = dense_mem.get("b").unwrap().as_f64().unwrap();
    assert!((bd - 2.0).abs() < 0.25, "dense memory exponent {bd}");
}

#[test]
fn ablation_driver_ranks_kernels() {
    // Close to the paper's setting (30x30 mesh, beta*=10, l_max=10) but
    // with a reduced walk/train budget: the reweighting-vs-ad-hoc gap
    // only shows once walks are long enough for 1/p(subwalk) to matter.
    let json = grfgp::exp::ablation::run(&args(&[
        "exp",
        "--side",
        "20",
        "--walks",
        "1500",
        "--train-iters",
        "80",
        "--max-len",
        "10",
    ]));
    let rows = json.as_arr().unwrap();
    assert_eq!(rows.len(), 3);
    let rmse_of = |name: &str| {
        rows.iter()
            .find(|r| r.get("kernel").unwrap().as_str() == Some(name))
            .unwrap()
            .get("rmse")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    // The paper's headline ablation finding: principled GRFs beat the
    // ad-hoc kernel.
    assert!(
        rmse_of("GRFs") < rmse_of("Ad-hoc GRFs"),
        "GRF {} should beat ad-hoc {}",
        rmse_of("GRFs"),
        rmse_of("Ad-hoc GRFs")
    );
}

#[test]
fn bo_synthetic_driver_runs() {
    let json = grfgp::exp::bo::run_synthetic(&args(&[
        "exp",
        "--side",
        "15",
        "--ring-n",
        "500",
        "--seeds",
        "1",
        "--n-steps",
        "20",
        "--n-init",
        "8",
        "--walks",
        "32",
    ]));
    let panels = json.as_arr().unwrap();
    assert_eq!(panels.len(), 4);
    for p in panels {
        let curves = p.get("curves").unwrap();
        for policy in ["grf-thompson", "random", "bfs", "dfs"] {
            let c = curves.get(policy).unwrap().as_arr().unwrap();
            assert_eq!(c.len(), 28, "panel {:?}", p.get("name"));
            // Regret curves are non-increasing.
            for w in c.windows(2) {
                assert!(
                    w[1].as_f64().unwrap() <= w[0].as_f64().unwrap() + 1e-9
                );
            }
        }
    }
}

#[test]
fn classify_driver_runs() {
    let json = grfgp::exp::classify::run(&args(&[
        "exp",
        "--scale",
        "0.15",
        "--seeds",
        "1",
        "--train-iters",
        "150",
        "--walks",
        "512",
    ]));
    let rows = json.as_arr().unwrap();
    assert_eq!(rows.len(), 3);
    for r in rows {
        let acc = r.get("accuracy_mean").unwrap().as_f64().unwrap();
        // Far above the ~30% majority-class baseline.
        assert!(acc > 40.0, "{:?} acc {acc}", r.get("kernel"));
    }
}

/// The mandated end-to-end driver: wind regression is a "real small
/// workload" through all layers (dataset → walks → train → pathwise
/// inference → metrics); recorded in EXPERIMENTS.md.
#[test]
fn wind_end_to_end_improves_over_prior() {
    let json = grfgp::exp::regression::run_wind(&args(&[
        "exp",
        "--res-deg",
        "12",
        "--walk-counts",
        "64",
        "--seeds",
        "1",
        "--train-iters",
        "25",
    ]));
    // Baseline: predicting the (standardised) train mean, i.e. zero.
    // Regenerate the seed-0 dataset the driver used to get the test sd
    // (the train set is a biased satellite-track sample, so test sd is
    // not exactly 1).
    let data = grfgp::datasets::wind::generate(
        grfgp::datasets::wind::Altitude::Low,
        12.0,
        &mut grfgp::util::rng::Rng::new(0),
    );
    let baseline = (data.test_y.iter().map(|v| v * v).sum::<f64>()
        / data.test_y.len() as f64)
        .sqrt();
    let rows = json.as_arr().unwrap();
    let best = rows
        .iter()
        .map(|r| r.get("rmse_mean").unwrap().as_f64().unwrap())
        .fold(f64::MAX, f64::min);
    assert!(
        best < 0.9 * baseline,
        "best GP RMSE {best} should beat the constant-prediction \
         baseline {baseline}"
    );
}
