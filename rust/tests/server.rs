//! Integration: the GP inference server — protocol round-trips,
//! concurrent clients, batching invariants (no request dropped or
//! duplicated, responses routed to the right client), and the
//! dynamic-graph ops (incremental add_edge/remove_edge/add_node with
//! the staleness guarantee: once a delta is acknowledged, no later
//! prediction is served from the pre-delta feature matrix).

use grfgp::gp::{GpModel, Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::server::batcher::{Batcher, Request};
use grfgp::server::{handle, ModelState, ServerConfig, ServerState};
use grfgp::stream::StreamingFeatures;
use grfgp::util::json::Json;
use grfgp::util::rng::Rng;
use grfgp::walks::WalkConfig;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;

/// In-process server state over a ring graph (no sockets) — for tests
/// that assert on internals like the model-lock acquisition counter.
fn in_process_state(n: usize, seed: u64) -> (ServerState, Hypers, WalkConfig) {
    let g = generators::ring(n);
    let cfg = WalkConfig {
        n_walks: 16,
        p_halt: 0.1,
        max_len: 3,
        threads: 1,
        ..Default::default()
    };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
    let stream =
        StreamingFeatures::new(g, cfg.clone(), hypers.modulation.coeffs(), 0);
    let ms = ModelState::new(stream, hypers.clone(), seed);
    (
        ServerState::new(ms, ServerConfig::default()),
        hypers,
        cfg,
    )
}

fn start_server(n: usize) -> std::net::SocketAddr {
    let g = generators::ring(n);
    let cfg = WalkConfig { n_walks: 32, p_halt: 0.1, max_len: 3, threads: 1, ..Default::default() };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
    let stream = StreamingFeatures::new(g, cfg, hypers.modulation.coeffs(), 0);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        // Deliberately on the deprecated shim: this suite is the
        // compile-and-run coverage keeping `serve_on` working until
        // the `ServeOptions` migration window closes.
        #[allow(deprecated)]
        grfgp::server::serve_on(stream, hypers, listener, 7).unwrap();
    });
    addr
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn call(&mut self, body: &str) -> Json {
        self.stream.write_all(body.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(&line).expect("server must return valid JSON")
    }
}

#[test]
fn protocol_roundtrip() {
    let addr = start_server(256);
    let mut c = Client::connect(addr);

    // Errors are structured, not disconnects.
    let bad = c.call("not json");
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
    let bad2 = c.call(r#"{"op":"observe","node":99999,"y":1.0}"#);
    assert_eq!(bad2.get("ok").unwrap().as_bool(), Some(false));

    // Observe + predict + thompson + stats.
    for i in 0..10 {
        let r = c.call(&format!(
            r#"{{"op":"observe","node":{},"y":{}}}"#,
            i * 20,
            (i as f64 * 0.5).sin()
        ));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    }
    let p = c.call(r#"{"op":"predict","nodes":[0,1,2],"samples":4}"#);
    assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{p:?}");
    assert_eq!(p.get("mean").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(p.get("var").unwrap().as_arr().unwrap().len(), 3);
    for v in p.get("var").unwrap().as_arr().unwrap() {
        assert!(v.as_f64().unwrap() > 0.0);
    }

    let t = c.call(r#"{"op":"thompson"}"#);
    assert_eq!(t.get("exhausted").unwrap().as_bool(), Some(false), "{t:?}");
    let next = t.get("next").unwrap().as_usize().unwrap();
    assert!(next < 256);

    let s = c.call(r#"{"op":"stats"}"#);
    assert_eq!(s.get("n_obs").unwrap().as_usize(), Some(10));

    let bye = c.call(r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").unwrap().as_bool(), Some(true));
}

#[test]
fn concurrent_predicts_are_batched_and_correct() {
    let addr = start_server(512);
    // Seed some observations first.
    let mut seeder = Client::connect(addr);
    for i in 0..8 {
        seeder.call(&format!(
            r#"{{"op":"observe","node":{},"y":{}}}"#,
            i * 60,
            (i as f64).cos()
        ));
    }
    // Fire concurrent predict requests from several clients; each must
    // get exactly its own nodes back.
    let handles: Vec<_> = (0..6)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let nodes: Vec<usize> = (0..3).map(|j| k * 10 + j).collect();
                let body = format!(
                    r#"{{"op":"predict","nodes":[{},{},{}],"samples":4}}"#,
                    nodes[0], nodes[1], nodes[2]
                );
                let r = c.call(&body);
                assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
                let mean = r.get("mean").unwrap().as_arr().unwrap();
                assert_eq!(mean.len(), 3, "client {k} got wrong span");
                mean.iter().map(|v| v.as_f64().unwrap()).collect::<Vec<_>>()
            })
        })
        .collect();
    let results: Vec<Vec<f64>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    // All responses finite.
    for r in &results {
        for v in r {
            assert!(v.is_finite());
        }
    }
    let mut c = Client::connect(addr);
    c.call(r#"{"op":"shutdown"}"#);
}

#[test]
fn graph_deltas_apply_incrementally_and_stamp_predictions() {
    let addr = start_server(256);
    let mut c = Client::connect(addr);
    for i in 0..6 {
        c.call(&format!(
            r#"{{"op":"observe","node":{},"y":{}}}"#,
            i * 40,
            (i as f64 * 0.7).sin()
        ));
    }
    // Baseline prediction at version 0.
    let p0 = c.call(r#"{"op":"predict","nodes":[5],"samples":4}"#);
    assert_eq!(p0.get("graph_version").unwrap().as_usize(), Some(0));

    // add_edge: incremental (resamples a strict subset of walks),
    // warm-solved, version bumped.
    let r = c.call(r#"{"op":"add_edge","u":5,"v":130,"w":0.8}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    assert_eq!(r.get("graph_version").unwrap().as_usize(), Some(1));
    let resampled = r.get("resampled_walks").unwrap().as_usize().unwrap();
    assert!(resampled > 0 && resampled < 256 * 32, "resampled={resampled}");
    assert!(r.get("patched_rows").unwrap().as_usize().unwrap() > 0);

    // Staleness guard: after the delta is acknowledged, predictions
    // are computed from (and stamped with) the post-delta state.
    let p1 = c.call(r#"{"op":"predict","nodes":[5],"samples":4}"#);
    assert_eq!(p1.get("ok").unwrap().as_bool(), Some(true), "{p1:?}");
    assert_eq!(p1.get("graph_version").unwrap().as_usize(), Some(1));

    // remove_edge restores the ring; removing it again is an error.
    let r = c.call(r#"{"op":"remove_edge","u":5,"v":130}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    assert_eq!(r.get("graph_version").unwrap().as_usize(), Some(2));
    let bad = c.call(r#"{"op":"remove_edge","u":5,"v":130}"#);
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));

    // add_node grows the graph; the new node is immediately servable.
    let r = c.call(r#"{"op":"add_node"}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    assert_eq!(r.get("node").unwrap().as_usize(), Some(256));
    let p = c.call(r#"{"op":"predict","nodes":[256],"samples":4}"#);
    assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{p:?}");
    assert!(p.get("mean").unwrap().as_arr().unwrap()[0]
        .as_f64()
        .unwrap()
        .is_finite());

    let s = c.call(r#"{"op":"stats"}"#);
    assert_eq!(s.get("n_nodes").unwrap().as_usize(), Some(257));
    assert_eq!(s.get("graph_version").unwrap().as_usize(), Some(3));
    assert_eq!(s.get("deltas_applied").unwrap().as_usize(), Some(3));

    c.call(r#"{"op":"shutdown"}"#);
}

#[test]
fn mixed_write_traffic_coalesces_and_scatters_correctly() {
    let addr = start_server(384);
    // Concurrent clients: observes and graph deltas interleaved. Every
    // client must get its own well-formed response, observation counts
    // must add up, and all deltas must land.
    let handles: Vec<_> = (0..8)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                if k % 2 == 0 {
                    // Observer client: 4 observations each.
                    for j in 0..4 {
                        let node = (k * 40 + j * 7) % 384;
                        let r = c.call(&format!(
                            r#"{{"op":"observe","node":{node},"y":{}}}"#,
                            (node as f64 * 0.1).sin()
                        ));
                        assert_eq!(
                            r.get("ok").unwrap().as_bool(),
                            Some(true),
                            "observer {k}: {r:?}"
                        );
                        assert!(r.get("n_obs").unwrap().as_usize().unwrap() >= 1);
                    }
                } else {
                    // Mutator client: one edge toggle.
                    let (u, v) = (k * 13 % 384, (k * 13 + 192) % 384);
                    let r = c.call(&format!(
                        r#"{{"op":"add_edge","u":{u},"v":{v},"w":0.4}}"#
                    ));
                    assert_eq!(
                        r.get("ok").unwrap().as_bool(),
                        Some(true),
                        "mutator {k}: {r:?}"
                    );
                    assert!(r.get("graph_version").unwrap().as_usize().unwrap() >= 1);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let mut c = Client::connect(addr);
    let s = c.call(r#"{"op":"stats"}"#);
    assert_eq!(s.get("n_obs").unwrap().as_usize(), Some(16), "{s:?}");
    assert_eq!(s.get("deltas_applied").unwrap().as_usize(), Some(4), "{s:?}");
    assert_eq!(s.get("graph_version").unwrap().as_usize(), Some(4), "{s:?}");
    // Post-delta predictions reflect every acknowledged delta.
    let p = c.call(r#"{"op":"predict","nodes":[0,100],"samples":4}"#);
    assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{p:?}");
    assert_eq!(p.get("graph_version").unwrap().as_usize(), Some(4));
    c.call(r#"{"op":"shutdown"}"#);
}

#[test]
fn self_loop_deltas_roundtrip_through_server() {
    let addr = start_server(128);
    let mut c = Client::connect(addr);
    let s0 = c.call(r#"{"op":"stats"}"#);
    let e0 = s0.get("n_edges").unwrap().as_usize().unwrap();

    // add_edge(u,u): valid delta, single directed entry, counts once.
    let r = c.call(r#"{"op":"add_edge","u":9,"v":9,"w":0.6}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    assert_eq!(r.get("graph_version").unwrap().as_usize(), Some(1));
    assert!(r.get("resampled_walks").unwrap().as_usize().unwrap() > 0);
    let s1 = c.call(r#"{"op":"stats"}"#);
    assert_eq!(s1.get("n_edges").unwrap().as_usize(), Some(e0 + 1), "{s1:?}");

    // Predictions still serve and are stamped post-delta.
    let p = c.call(r#"{"op":"predict","nodes":[9],"samples":4}"#);
    assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{p:?}");
    assert_eq!(p.get("graph_version").unwrap().as_usize(), Some(1));
    assert!(p.get("mean").unwrap().as_arr().unwrap()[0]
        .as_f64()
        .unwrap()
        .is_finite());

    // remove_edge(u,u) restores the edge count; removing again errors.
    let r = c.call(r#"{"op":"remove_edge","u":9,"v":9}"#);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    assert_eq!(r.get("graph_version").unwrap().as_usize(), Some(2));
    let s2 = c.call(r#"{"op":"stats"}"#);
    assert_eq!(s2.get("n_edges").unwrap().as_usize(), Some(e0), "{s2:?}");
    let bad = c.call(r#"{"op":"remove_edge","u":9,"v":9}"#);
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false), "{bad:?}");

    c.call(r#"{"op":"shutdown"}"#);
}

/// Mixed traffic across forced overlay-compaction boundaries: delta
/// batches, observes, and predicts interleave with the stream's
/// compaction threshold at 1, so every delta folds the stream AND
/// model overlays mid-serving. `graph_version` must stay monotone and
/// every served prediction must be **bitwise** what a from-scratch
/// rebuild of the mutated graph computes under the same rng stream.
#[test]
fn compaction_boundary_keeps_predictions_bitwise_and_versions_monotone() {
    let n = 192;
    let g = generators::ring(n);
    let cfg = WalkConfig { n_walks: 24, p_halt: 0.1, max_len: 3, threads: 1, ..Default::default() };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
    let mut stream = StreamingFeatures::new(
        g.clone(),
        cfg.clone(),
        hypers.modulation.coeffs(),
        0,
    );
    // Force a compaction on every delta batch.
    stream.set_compact_threshold(1);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let hypers_srv = hypers.clone();
    std::thread::spawn(move || {
        grfgp::server::ServeOptions::new()
            .seed(7)
            .serve_on(stream, hypers_srv, listener)
            .unwrap();
    });
    let mut c = Client::connect(addr);
    let probe_nodes = [0usize, 45, 131];
    let mut g2 = g;
    let mut obs: Vec<(usize, f64)> = Vec::new();
    let mut last_version = 0usize;
    for (k, &(u, v, w)) in
        [(3usize, 90usize, 0.8f64), (10, 100, 0.6), (50, 140, 0.5)]
            .iter()
            .enumerate()
    {
        // Observe...
        let node = 7 + k * 30;
        let yv = (node as f64 * 0.3).sin();
        let r = c.call(&format!(
            r#"{{"op":"observe","node":{node},"y":{yv}}}"#
        ));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        obs.push((node, yv));
        // ...delta (each one crosses a compaction boundary)...
        let r = c.call(&format!(
            r#"{{"op":"add_edge","u":{u},"v":{v},"w":{w}}}"#
        ));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert_eq!(
            r.get("compacted").unwrap().as_bool(),
            Some(true),
            "threshold 1 must compact every batch: {r:?}"
        );
        let ver = r.get("graph_version").unwrap().as_usize().unwrap();
        assert!(ver > last_version, "version not monotone: {ver} after {last_version}");
        last_version = ver;
        g2.add_edge(u, v, w);
        // ...predict straight after the fold.
        let p = c.call(&format!(
            r#"{{"op":"predict","nodes":[{},{},{}],"samples":4}}"#,
            probe_nodes[0], probe_nodes[1], probe_nodes[2]
        ));
        assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{p:?}");
        assert_eq!(
            p.get("graph_version").unwrap().as_usize(),
            Some(ver),
            "prediction stamped with a stale version"
        );
        // Reference: model rebuilt from scratch on the mutated graph,
        // same observations, same rng stream as the server's predict.
        let full = StreamingFeatures::new(
            g2.clone(),
            cfg.clone(),
            hypers.modulation.coeffs(),
            0,
        );
        let mut model =
            GpModel::new(full.components(), hypers.clone(), &[], &[]);
        let nodes: Vec<usize> = obs.iter().map(|o| o.0).collect();
        let ys: Vec<f64> = obs.iter().map(|o| o.1).collect();
        model.set_data(&nodes, &ys);
        // The response's (graph_version, rng_seq) pair fully determines
        // the prediction: rng = server_rng.split(0xBA7C).split(rng_seq)
        // (see server::snapshot docs). Observes don't advance the
        // server rng, so its base is still the seed.
        let seq = p.get("rng_seq").unwrap().as_usize().unwrap() as u64;
        let mut rng = Rng::new(7).split(0xBA7C).split(seq);
        let (mean, var) = model.predict(4, &mut rng);
        let served_mean = p.get("mean").unwrap().as_arr().unwrap();
        let served_var = p.get("var").unwrap().as_arr().unwrap();
        for (j, &node) in probe_nodes.iter().enumerate() {
            // The JSON writer emits shortest-roundtrip floats, so the
            // served numbers parse back to exactly the served bits.
            assert_eq!(
                served_mean[j].as_f64().unwrap(),
                mean[node],
                "step {k}: mean at node {node} not bitwise the rebuild"
            );
            assert_eq!(
                served_var[j].as_f64().unwrap(),
                var[node],
                "step {k}: var at node {node} not bitwise the rebuild"
            );
        }
    }
    let s = c.call(r#"{"op":"stats"}"#);
    assert_eq!(s.get("overlay_rows").unwrap().as_usize(), Some(0), "{s:?}");
    c.call(r#"{"op":"shutdown"}"#);
}

/// Tentpole invariant: `predict` is wait-free — neither the direct
/// handler path nor the batcher path may acquire the model mutex. The
/// lifetime lock-acquisition counter must not move across any number
/// of predicts through either entry point.
#[test]
fn predicts_never_acquire_the_model_lock() {
    let (state, _, _) = in_process_state(96, 7);
    for i in 0..4 {
        let r = handle(
            &state,
            &Request::Observe { node: i * 20, y: (i as f64).cos() },
        );
        assert!(r.ok, "{r:?}");
    }
    let batcher = Batcher::new(8);
    let before = state.model_lock_acquisitions.load(Ordering::SeqCst);
    for i in 0..5 {
        let r = handle(
            &state,
            &Request::Predict { nodes: vec![i, i + 30], samples: 2 },
        );
        assert!(r.ok, "{r:?}");
        let r = batcher.submit(
            &state,
            Request::Predict { nodes: vec![i + 1, i + 50], samples: 2 },
        );
        assert!(r.ok, "{r:?}");
    }
    let after = state.model_lock_acquisitions.load(Ordering::SeqCst);
    assert_eq!(
        before, after,
        "a predict path acquired the model mutex ({} -> {})",
        before, after
    );
}

/// The two predict entry points (`handle` and the batcher) are one
/// implementation: with the same snapshot and rng sequence rule, both
/// must serve numbers bitwise-identical to a from-scratch model driven
/// by `server_rng.split(0xBA7C).split(rng_seq)`.
#[test]
fn both_predict_entry_points_are_bitwise_identical() {
    let (state, hypers, cfg) = in_process_state(96, 7);
    let obs = [(3usize, 0.5f64), (40, -0.2), (77, 1.1)];
    for &(node, y) in &obs {
        let r = handle(&state, &Request::Observe { node, y });
        assert!(r.ok, "{r:?}");
    }
    let batcher = Batcher::new(8);
    let nodes = vec![0usize, 9, 55];
    let direct =
        handle(&state, &Request::Predict { nodes: nodes.clone(), samples: 4 });
    let batched = batcher
        .submit(&state, Request::Predict { nodes: nodes.clone(), samples: 4 });
    // Reference: model rebuilt from scratch (same graph seed), same
    // observations, rng derived purely from the echoed rng_seq.
    let g = generators::ring(96);
    let full = StreamingFeatures::new(g, cfg, hypers.modulation.coeffs(), 0);
    let mut model = GpModel::new(full.components(), hypers, &[], &[]);
    model.set_data(&[3, 40, 77], &[0.5, -0.2, 1.1]);
    for (label, resp) in [("handle", direct), ("batcher", batched)] {
        let j = resp.to_json();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{label}: {j:?}");
        let seq = j.get("rng_seq").unwrap().as_usize().unwrap() as u64;
        let mut rng = Rng::new(7).split(0xBA7C).split(seq);
        let (mean, var) = model.predict(4, &mut rng);
        let served_mean = j.get("mean").unwrap().as_arr().unwrap();
        let served_var = j.get("var").unwrap().as_arr().unwrap();
        for (k, &node) in nodes.iter().enumerate() {
            assert_eq!(
                served_mean[k].as_f64().unwrap(),
                mean[node],
                "{label}: mean at node {node} not bitwise the reference"
            );
            assert_eq!(
                served_var[k].as_f64().unwrap(),
                var[node],
                "{label}: var at node {node} not bitwise the reference"
            );
        }
    }
}

/// Regression: a NaN observation used to panic `sample`/`thompson` at
/// the `partial_cmp(..).unwrap()` ranking step. It must now surface as
/// a typed `internal` error — and the server must keep serving after.
#[test]
fn nan_poisoned_posterior_yields_typed_error_not_panic() {
    let (state, _, _) = in_process_state(16, 7);
    let r = handle(&state, &Request::Observe { node: 0, y: f64::NAN });
    assert!(r.ok, "observe does not validate y: {r:?}");
    for req in [Request::Sample, Request::Thompson] {
        let resp = handle(&state, &req);
        let j = resp.to_json();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "{j:?}");
        assert_eq!(
            j.get("error_kind").unwrap().as_str(),
            Some("internal"),
            "{j:?}"
        );
    }
    // Not a one-shot: the handler stays up and keeps answering.
    let again = handle(&state, &Request::Sample).to_json();
    assert_eq!(again.get("ok").unwrap().as_bool(), Some(false));
}

/// Regression: `thompson` with every node already queried used to fall
/// back to `unwrap_or(0)` — silently re-recommending node 0. It must
/// now say `exhausted: true` and carry no `next` field.
#[test]
fn thompson_reports_exhaustion_instead_of_node_zero() {
    let addr = start_server(4);
    let mut c = Client::connect(addr);
    for node in 0..4 {
        let r = c.call(&format!(
            r#"{{"op":"observe","node":{node},"y":{}}}"#,
            node as f64 * 0.2
        ));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    }
    let t = c.call(r#"{"op":"thompson"}"#);
    assert_eq!(t.get("ok").unwrap().as_bool(), Some(true), "{t:?}");
    assert_eq!(t.get("exhausted").unwrap().as_bool(), Some(true), "{t:?}");
    assert!(t.get("next").is_none(), "exhausted reply must not name a node: {t:?}");
    c.call(r#"{"op":"shutdown"}"#);
}

/// Satellite stress test: mixed predict/delta traffic with the overlay
/// compaction threshold forced to 1, so every write batch folds the
/// stream and model overlays mid-serving. Asserts, per connection,
/// that `graph_version` is monotone; that the writer finishes while
/// readers stay pinned on predicts (wait-free reads can't starve
/// writers); and — after the race — that every served response is
/// bitwise what a from-scratch model at its stamped version computes
/// under its echoed `rng_seq`.
#[test]
fn concurrent_predicts_and_deltas_stay_consistent_across_compactions() {
    let n = 128;
    let g = generators::ring(n);
    let cfg = WalkConfig {
        n_walks: 16,
        p_halt: 0.1,
        max_len: 3,
        threads: 1,
        ..Default::default()
    };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
    let mut stream = StreamingFeatures::new(
        g.clone(),
        cfg.clone(),
        hypers.modulation.coeffs(),
        0,
    );
    stream.set_compact_threshold(1); // every delta compacts
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let hypers_srv = hypers.clone();
    std::thread::spawn(move || {
        grfgp::server::ServeOptions::new()
            .seed(7)
            .serve_on(stream, hypers_srv, listener)
            .unwrap();
    });
    // Fixed observations seeded before the race, so a reference rebuild
    // varies only by graph version.
    let obs: Vec<(usize, f64)> =
        (0..5).map(|i| (i * 25, (i as f64 * 0.4).sin())).collect();
    let mut c = Client::connect(addr);
    for &(node, y) in &obs {
        let r =
            c.call(&format!(r#"{{"op":"observe","node":{node},"y":{y}}}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    }
    let edges: Vec<(usize, usize, f64)> =
        (0..6).map(|k| (k * 17 % n, (k * 17 + 64) % n, 0.5)).collect();
    let writer = {
        let edges = edges.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            for (i, &(u, v, w)) in edges.iter().enumerate() {
                let r = c.call(&format!(
                    r#"{{"op":"add_edge","u":{u},"v":{v},"w":{w}}}"#
                ));
                assert_eq!(
                    r.get("ok").unwrap().as_bool(),
                    Some(true),
                    "writer delta {i}: {r:?}"
                );
                // Single sequential writer ⇒ versions 1..=len in order.
                assert_eq!(
                    r.get("graph_version").unwrap().as_usize(),
                    Some(i + 1),
                    "{r:?}"
                );
            }
        })
    };
    let probe = [0usize, 33, 90];
    let readers: Vec<_> = (0..3)
        .map(|_| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let mut last = 0usize;
                let mut seen: Vec<(usize, usize, Vec<f64>, Vec<f64>)> =
                    Vec::new();
                for _ in 0..8 {
                    let p = c.call(
                        r#"{"op":"predict","nodes":[0,33,90],"samples":2}"#,
                    );
                    assert_eq!(
                        p.get("ok").unwrap().as_bool(),
                        Some(true),
                        "{p:?}"
                    );
                    let ver =
                        p.get("graph_version").unwrap().as_usize().unwrap();
                    assert!(
                        ver >= last,
                        "per-connection version went backwards: {ver} < {last}"
                    );
                    last = ver;
                    let seq = p.get("rng_seq").unwrap().as_usize().unwrap();
                    let mean: Vec<f64> = p
                        .get("mean")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|v| v.as_f64().unwrap())
                        .collect();
                    let var: Vec<f64> = p
                        .get("var")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|v| v.as_f64().unwrap())
                        .collect();
                    seen.push((ver, seq, mean, var));
                }
                seen
            })
        })
        .collect();
    let responses: Vec<(usize, usize, Vec<f64>, Vec<f64>)> = readers
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();
    writer
        .join()
        .expect("writer must make progress while readers stay pinned");
    // Post-hoc bitwise verification: version v ⇔ the first v edges.
    let obs_nodes: Vec<usize> = obs.iter().map(|o| o.0).collect();
    let obs_ys: Vec<f64> = obs.iter().map(|o| o.1).collect();
    let mut models: std::collections::HashMap<usize, GpModel> =
        std::collections::HashMap::new();
    for (ver, seq, mean, var) in responses {
        let model = models.entry(ver).or_insert_with(|| {
            let mut gv = g.clone();
            for &(u, v, w) in &edges[..ver] {
                gv.add_edge(u, v, w);
            }
            let full = StreamingFeatures::new(
                gv,
                cfg.clone(),
                hypers.modulation.coeffs(),
                0,
            );
            let mut m =
                GpModel::new(full.components(), hypers.clone(), &[], &[]);
            m.set_data(&obs_nodes, &obs_ys);
            m
        });
        let mut rng = Rng::new(7).split(0xBA7C).split(seq as u64);
        let (rm, rv) = model.predict(2, &mut rng);
        for (j, &node) in probe.iter().enumerate() {
            assert_eq!(
                mean[j], rm[node],
                "v{ver} seq{seq}: mean at node {node} not bitwise"
            );
            assert_eq!(
                var[j], rv[node],
                "v{ver} seq{seq}: var at node {node} not bitwise"
            );
        }
    }
    let mut c = Client::connect(addr);
    c.call(r#"{"op":"shutdown"}"#);
}

/// A pipelined client writes many frames in ONE socket write; the
/// server must answer each with exactly one complete reply, in request
/// order — the streaming decoder may not drop, reorder, or merge
/// pipelined frames even when the batcher coalesces their handling.
#[test]
fn pipelined_frames_get_ordered_complete_replies() {
    let addr = start_server(64);
    let mut c = Client::connect(addr);
    // 8 observes (distinct nodes → n_obs counts 1..=8 in order), one
    // stats, then 3 predicts of strictly growing span (mean length
    // identifies which reply is which).
    let mut body = String::new();
    for i in 0..8 {
        body.push_str(&format!(
            "{{\"op\":\"observe\",\"node\":{},\"y\":{}}}\n",
            i * 7,
            (i as f64 * 0.3).sin()
        ));
    }
    body.push_str("{\"op\":\"stats\"}\n");
    for k in 1..=3usize {
        let nodes: Vec<String> = (0..k).map(|j| (j * 5).to_string()).collect();
        body.push_str(&format!(
            "{{\"op\":\"predict\",\"nodes\":[{}],\"samples\":2}}\n",
            nodes.join(",")
        ));
    }
    c.stream.write_all(body.as_bytes()).unwrap();
    let mut reply = || {
        let mut line = String::new();
        c.reader.read_line(&mut line).unwrap();
        assert!(line.ends_with('\n'), "truncated reply: {line:?}");
        Json::parse(&line).expect("complete JSON reply")
    };
    for i in 0..8 {
        let r = reply();
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "obs {i}: {r:?}");
        assert_eq!(
            r.get("n_obs").unwrap().as_usize(),
            Some(i + 1),
            "observe replies out of order"
        );
    }
    let s = reply();
    assert_eq!(s.get("n_obs").unwrap().as_usize(), Some(8), "{s:?}");
    for k in 1..=3usize {
        let p = reply();
        assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{p:?}");
        assert_eq!(
            p.get("mean").unwrap().as_arr().unwrap().len(),
            k,
            "predict replies out of order"
        );
    }
    let mut c2 = Client::connect(addr);
    c2.call(r#"{"op":"shutdown"}"#);
}

/// Satellite smoke test: the `--metrics-addr` HTTP exposition listener
/// answers `GET /metrics` with the Prometheus text rendering over a
/// plain TCP socket (no JSON wire protocol involved), and 404s
/// everything else.
#[test]
fn metrics_http_listener_serves_prometheus_text() {
    use std::io::Read;
    // Reserve an ephemeral port for the metrics listener (bind, read,
    // drop) — the server re-binds it via config.metrics_addr.
    let metrics_addr = {
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().to_string()
    };
    let g = generators::ring(64);
    let cfg = WalkConfig {
        n_walks: 8,
        p_halt: 0.1,
        max_len: 3,
        threads: 1,
        ..Default::default()
    };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
    let stream = StreamingFeatures::new(g, cfg, hypers.modulation.coeffs(), 0);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let opts = grfgp::server::ServeOptions::new()
        .metrics_addr(metrics_addr.clone())
        .seed(7);
    std::thread::spawn(move || {
        opts.serve_on(stream, hypers, listener).unwrap();
    });
    // Generate some traffic so the scrape has non-zero counters.
    let mut c = Client::connect(addr);
    let p = c.call(r#"{"op":"predict","nodes":[0,1],"samples":2}"#);
    assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{p:?}");

    let http_get = |target: &str| -> String {
        let mut s = TcpStream::connect(&metrics_addr).unwrap();
        s.write_all(
            format!("GET {target} HTTP/1.0\r\nHost: x\r\n\r\n").as_bytes(),
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };
    let resp = http_get("/metrics");
    assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp:?}");
    assert!(
        resp.contains("text/plain; version=0.0.4"),
        "missing exposition content type: {resp:?}"
    );
    let body = resp
        .split_once("\r\n\r\n")
        .expect("header/body separator")
        .1;
    assert!(
        body.contains("grfgp_req_predict"),
        "scrape body missing request counters: {body:?}"
    );
    assert!(
        body.contains("# TYPE"),
        "not Prometheus text exposition: {body:?}"
    );
    let miss = http_get("/not-metrics");
    assert!(miss.starts_with("HTTP/1.0 404"), "{miss:?}");

    c.call(r#"{"op":"shutdown"}"#);
}

#[test]
fn concurrent_deltas_get_distinct_monotone_versions() {
    // Coalesced delta runs must still stamp one monotone graph_version
    // per delta: with 6 concurrent mutators, the acked versions are a
    // permutation of 1..=6 regardless of how the write batcher grouped
    // them into engine calls.
    let addr = start_server(256);
    let handles: Vec<_> = (0..6)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let (u, v) = (k * 11 % 256, (k * 11 + 128) % 256);
                let r = c.call(&format!(
                    r#"{{"op":"add_edge","u":{u},"v":{v},"w":0.3}}"#
                ));
                assert_eq!(
                    r.get("ok").unwrap().as_bool(),
                    Some(true),
                    "mutator {k}: {r:?}"
                );
                r.get("graph_version").unwrap().as_usize().unwrap()
            })
        })
        .collect();
    let mut versions: Vec<usize> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    versions.sort_unstable();
    assert_eq!(versions, vec![1, 2, 3, 4, 5, 6], "versions not distinct/monotone");
    let mut c = Client::connect(addr);
    let s = c.call(r#"{"op":"stats"}"#);
    assert_eq!(s.get("graph_version").unwrap().as_usize(), Some(6), "{s:?}");
    assert_eq!(s.get("deltas_applied").unwrap().as_usize(), Some(6), "{s:?}");
    c.call(r#"{"op":"shutdown"}"#);
}
