//! Integration: the GP inference server — protocol round-trips,
//! concurrent clients, batching invariants (no request dropped or
//! duplicated, responses routed to the right client).

use grfgp::gp::{GpModel, Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::util::json::Json;
use grfgp::walks::{sample_components, WalkConfig};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

fn start_server(n: usize) -> std::net::SocketAddr {
    let g = generators::ring(n);
    let cfg = WalkConfig { n_walks: 32, p_halt: 0.1, max_len: 3, threads: 1, ..Default::default() };
    let comps = sample_components(&g, &cfg, 0);
    let model = GpModel::new(
        comps,
        Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1),
        &[],
        &[],
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        grfgp::server::serve_on(model, listener, 7).unwrap();
    });
    addr
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn call(&mut self, body: &str) -> Json {
        self.stream.write_all(body.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(&line).expect("server must return valid JSON")
    }
}

#[test]
fn protocol_roundtrip() {
    let addr = start_server(256);
    let mut c = Client::connect(addr);

    // Errors are structured, not disconnects.
    let bad = c.call("not json");
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
    let bad2 = c.call(r#"{"op":"observe","node":99999,"y":1.0}"#);
    assert_eq!(bad2.get("ok").unwrap().as_bool(), Some(false));

    // Observe + predict + thompson + stats.
    for i in 0..10 {
        let r = c.call(&format!(
            r#"{{"op":"observe","node":{},"y":{}}}"#,
            i * 20,
            (i as f64 * 0.5).sin()
        ));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    }
    let p = c.call(r#"{"op":"predict","nodes":[0,1,2],"samples":4}"#);
    assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{p:?}");
    assert_eq!(p.get("mean").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(p.get("var").unwrap().as_arr().unwrap().len(), 3);
    for v in p.get("var").unwrap().as_arr().unwrap() {
        assert!(v.as_f64().unwrap() > 0.0);
    }

    let t = c.call(r#"{"op":"thompson"}"#);
    let next = t.get("next").unwrap().as_usize().unwrap();
    assert!(next < 256);

    let s = c.call(r#"{"op":"stats"}"#);
    assert_eq!(s.get("n_obs").unwrap().as_usize(), Some(10));

    let bye = c.call(r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").unwrap().as_bool(), Some(true));
}

#[test]
fn concurrent_predicts_are_batched_and_correct() {
    let addr = start_server(512);
    // Seed some observations first.
    let mut seeder = Client::connect(addr);
    for i in 0..8 {
        seeder.call(&format!(
            r#"{{"op":"observe","node":{},"y":{}}}"#,
            i * 60,
            (i as f64).cos()
        ));
    }
    // Fire concurrent predict requests from several clients; each must
    // get exactly its own nodes back.
    let handles: Vec<_> = (0..6)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let nodes: Vec<usize> = (0..3).map(|j| k * 10 + j).collect();
                let body = format!(
                    r#"{{"op":"predict","nodes":[{},{},{}],"samples":4}}"#,
                    nodes[0], nodes[1], nodes[2]
                );
                let r = c.call(&body);
                assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
                let mean = r.get("mean").unwrap().as_arr().unwrap();
                assert_eq!(mean.len(), 3, "client {k} got wrong span");
                mean.iter().map(|v| v.as_f64().unwrap()).collect::<Vec<_>>()
            })
        })
        .collect();
    let results: Vec<Vec<f64>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    // All responses finite.
    for r in &results {
        for v in r {
            assert!(v.is_finite());
        }
    }
    let mut c = Client::connect(addr);
    c.call(r#"{"op":"shutdown"}"#);
}
