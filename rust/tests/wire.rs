//! Fault-injection and property suite for the hardened serving edge.
//!
//! Three layers of attack, mirroring the jsonmodem-style fuzz
//! methodology on the decoder and adding live-server fault injection:
//!
//! 1. **Decoder properties** (no sockets): random valid JSON frames
//!    round-trip bitwise through the streaming decoder regardless of
//!    how the byte stream is chunked; random byte mutations never
//!    panic and never desynchronise the frame stream; depth bombs and
//!    oversized frames produce clean typed errors with the reassembly
//!    buffer provably bounded.
//! 2. **Malformed-input battery over real TCP**: binary garbage, lone
//!    surrogates, unterminated strings, nesting past the depth cap,
//!    frame-cap violations, negative ids — each costs one typed error
//!    line and the connection/server stays healthy.
//! 3. **Lifecycle faults**: shutdown completes with idle connections
//!    attached (the old reader hung forever), the connection cap
//!    rejects gracefully and recovers, a panicking handler (injected
//!    via the test-only `fault` op) is isolated even while holding the
//!    model lock, mid-frame disconnects are harmless, and a
//!    well-behaved client receives bitwise-identical bytes whether or
//!    not a storm of garbage clients hammers the server concurrently.
//!
//! Property-test iteration counts default low enough for the tier-1
//! suite and scale up in CI via `WIRE_FUZZ_CASES`.

use grfgp::gp::{Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::prop_assert;
use grfgp::server::wire::{ErrorKind, WireConfig, WireDecoder, WireError};
use grfgp::server::ServerConfig;
use grfgp::stream::StreamingFeatures;
use grfgp::util::json::{Json, UnicodeMode};
use grfgp::util::proptest::proptest;
use grfgp::util::rng::Rng;
use grfgp::walks::WalkConfig;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Property-test case count: low for the tier-1 run, raised in CI.
fn fuzz_cases(default: usize) -> usize {
    std::env::var("WIRE_FUZZ_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

// ---------------------------------------------------------------------
// Random JSON generation (serializer-compatible: finite numbers only,
// so `parse(to_string(v)) == v` holds bitwise).
// ---------------------------------------------------------------------

fn random_string(rng: &mut Rng) -> String {
    let len = rng.below(12);
    (0..len)
        .map(|_| match rng.below(10) {
            0 => '"',
            1 => '\\',
            2 => '\n',
            3 => '\u{1}',
            4 => '😀',
            5 => 'é',
            6 => '\t',
            _ => (b'a' + rng.below(26) as u8) as char,
        })
        .collect()
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    let pick = if depth == 0 { rng.below(4) } else { rng.below(6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.bernoulli(0.5)),
        2 => {
            // Spread magnitudes across ~12 decades; always finite.
            let mag = 10f64.powi(rng.below(13) as i32 - 6);
            Json::Num(rng.normal() * mag)
        }
        3 => Json::Str(random_string(rng)),
        4 => Json::Arr(
            (0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|_| (random_string(rng), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// Feed `blob` to the decoder in random-sized chunks (1..=7 bytes).
fn feed_chunked(
    rng: &mut Rng,
    dec: &mut WireDecoder,
    blob: &[u8],
) -> Vec<Result<Json, WireError>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < blob.len() {
        let k = 1 + rng.below(7);
        let end = (i + k).min(blob.len());
        dec.feed(&blob[i..end], &mut out);
        i = end;
    }
    out
}

// ---------------------------------------------------------------------
// 1. Decoder properties
// ---------------------------------------------------------------------

#[test]
fn decoder_roundtrips_random_frames_in_random_chunks() {
    proptest(fuzz_cases(48), |rng| {
        let n_frames = 1 + rng.below(8);
        let frames: Vec<Json> =
            (0..n_frames).map(|_| random_json(rng, 3)).collect();
        let mut blob = Vec::new();
        for f in &frames {
            blob.extend_from_slice(f.to_string().as_bytes());
            blob.push(b'\n');
        }
        let mut dec = WireDecoder::new(WireConfig::default());
        let out = feed_chunked(rng, &mut dec, &blob);
        prop_assert!(
            out.len() == n_frames,
            "decoded {} of {} frames",
            out.len(),
            n_frames
        );
        for (got, want) in out.iter().zip(&frames) {
            match got {
                Ok(j) => prop_assert!(
                    j == want,
                    "frame mismatch: {j:?} vs {want:?}"
                ),
                Err(e) => {
                    return Err(format!(
                        "valid frame rejected ({}): {}",
                        e.msg,
                        want.to_string()
                    ))
                }
            }
        }
        prop_assert!(!dec.mid_frame(), "decoder left mid-frame");
        Ok(())
    });
}

#[test]
fn decoder_survives_random_byte_mutations() {
    proptest(fuzz_cases(48), |rng| {
        let mut blob = random_json(rng, 3).to_string().into_bytes();
        for _ in 0..(1 + rng.below(6)) {
            let i = rng.below(blob.len());
            blob[i] = rng.below(256) as u8;
        }
        blob.push(b'\n');
        // A pristine frame after the mutated one: the decoder must
        // resynchronise on the newline no matter what the mutation did.
        let follow = random_json(rng, 2);
        blob.extend_from_slice(follow.to_string().as_bytes());
        blob.push(b'\n');
        let cfg = WireConfig {
            max_frame_bytes: 1 << 16,
            max_parse_depth: 16,
            unicode: UnicodeMode::Strict,
        };
        let mut dec = WireDecoder::new(cfg);
        // Must not panic, whatever bytes the mutation produced.
        let out = feed_chunked(rng, &mut dec, &blob);
        // The mutated frame may decode, error, split (if a '\n' was
        // injected), or vanish (mutated to whitespace); the *last*
        // frame must always be the pristine one, decoded exactly.
        match out.last() {
            Some(Ok(j)) => {
                prop_assert!(j == &follow, "resync lost: {j:?} vs {follow:?}")
            }
            Some(Err(e)) => {
                return Err(format!("pristine follow-up rejected: {}", e.msg))
            }
            None => return Err("no frames decoded at all".to_string()),
        }
        Ok(())
    });
}

#[test]
fn decoder_replace_mode_substitutes_lone_surrogates() {
    let cfg = WireConfig {
        unicode: UnicodeMode::Replace,
        ..Default::default()
    };
    let mut dec = WireDecoder::new(cfg);
    let mut out = Vec::new();
    dec.feed(b"{\"s\":\"\\ud800\"}\n", &mut out);
    assert_eq!(out.len(), 1);
    let j = out[0].as_ref().expect("replace mode accepts lone surrogate");
    assert_eq!(j.get("s").unwrap().as_str().unwrap(), "\u{FFFD}");
    // The same frame under the strict default is a parse error.
    let mut strict = WireDecoder::new(WireConfig::default());
    out.clear();
    strict.feed(b"{\"s\":\"\\ud800\"}\n", &mut out);
    assert_eq!(out[0].as_ref().err().unwrap().kind, ErrorKind::Parse);
}

#[test]
fn decoder_memory_stays_bounded_under_megabyte_line_bomb() {
    let cfg = WireConfig { max_frame_bytes: 4096, ..Default::default() };
    let mut dec = WireDecoder::new(cfg);
    let mut out = Vec::new();
    let junk = vec![b'x'; 8 * 1024];
    for _ in 0..256 {
        // 2 MiB total without a newline.
        dec.feed(&junk, &mut out);
        assert!(
            dec.buffered() <= 4096,
            "reassembly buffer exceeded max_frame_bytes"
        );
    }
    assert!(out.is_empty(), "no frame completed yet");
    dec.feed(b"\n{\"op\":\"stats\"}\n", &mut out);
    assert_eq!(out.len(), 2);
    let err = out[0].as_ref().err().expect("bomb must yield one error");
    assert_eq!(err.kind, ErrorKind::Protocol);
    assert!(err.msg.contains("max_frame_bytes"), "{}", err.msg);
    assert!(out[1].is_ok(), "decoder must recover after the bomb");
}

// ---------------------------------------------------------------------
// Server harness
// ---------------------------------------------------------------------

fn start_server_with(
    n: usize,
    config: ServerConfig,
) -> (std::net::SocketAddr, JoinHandle<()>) {
    let g = generators::ring(n);
    let cfg = WalkConfig {
        n_walks: 16,
        p_halt: 0.1,
        max_len: 3,
        threads: 1,
        ..Default::default()
    };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
    let stream = StreamingFeatures::new(g, cfg, hypers.modulation.coeffs(), 0);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        // Deliberately on the deprecated shim: this harness is the
        // compile-and-run coverage keeping `serve_on_with` working
        // until the `ServeOptions` migration window closes.
        #[allow(deprecated)]
        grfgp::server::serve_on_with(stream, hypers, listener, 7, config)
            .unwrap();
    });
    (addr, handle)
}

/// Fast-polling config so shutdown/idle tests finish quickly.
fn quick_config() -> ServerConfig {
    ServerConfig {
        read_timeout: Duration::from_millis(50),
        ..Default::default()
    }
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn call_raw(&mut self, body: &[u8]) -> String {
        self.stream.write_all(body).unwrap();
        self.stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line
    }

    fn call(&mut self, body: &str) -> Json {
        let line = self.call_raw(body.as_bytes());
        Json::parse(&line).expect("server must return valid JSON")
    }

    fn call_bytes(&mut self, body: &[u8]) -> Json {
        let line = self.call_raw(body);
        Json::parse(&line).expect("server must return valid JSON")
    }
}

fn assert_kind(resp: &Json, kind: &str) {
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp:?}");
    assert_eq!(
        resp.get("error_kind").unwrap().as_str(),
        Some(kind),
        "{resp:?}"
    );
}

fn assert_ok(resp: &Json) {
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
}

/// Join the server thread with a deadline — a hang here is exactly the
/// regression these tests exist to catch.
fn join_within(handle: JoinHandle<()>, within: Duration, what: &str) {
    let deadline = Instant::now() + within;
    while !handle.is_finished() {
        assert!(Instant::now() < deadline, "server did not exit: {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
    handle.join().unwrap();
}

// ---------------------------------------------------------------------
// 2. Malformed-input battery over real TCP
// ---------------------------------------------------------------------

#[test]
fn malformed_battery_yields_typed_errors_and_connection_stays_healthy() {
    let config = ServerConfig {
        wire: WireConfig {
            max_frame_bytes: 4096,
            max_parse_depth: 16,
            unicode: UnicodeMode::Strict,
        },
        ..quick_config()
    };
    let (addr, handle) = start_server_with(64, config);
    let mut c = Client::connect(addr);

    // Binary garbage.
    let r = c.call_bytes(&[0xFF, 0xFE, 0x00, 0x80, b'{']);
    assert_kind(&r, "parse");
    // Lone surrogate (strict default).
    let r = c.call(r#"{"bad":"\ud800"}"#);
    assert_kind(&r, "parse");
    // Unterminated string.
    let r = c.call(r#"{"op":"sta"#);
    assert_kind(&r, "parse");
    // Nesting past the depth cap.
    let bomb = format!("{}1{}", "[".repeat(64), "]".repeat(64));
    let r = c.call(&bomb);
    assert_kind(&r, "parse");
    assert!(
        r.get("error").unwrap().as_str().unwrap().contains("max_depth"),
        "{r:?}"
    );
    // Line exceeding the frame cap (~12 KB against a 4 KiB cap).
    let big = format!(
        r#"{{"op":"predict","nodes":[{}]}}"#,
        vec!["1"; 6000].join(",")
    );
    let r = c.call(&big);
    assert_kind(&r, "protocol");
    assert!(
        r.get("error").unwrap().as_str().unwrap().contains("max_frame_bytes"),
        "{r:?}"
    );
    // Valid JSON, unknown op.
    let r = c.call(r#"{"op":"frobnicate"}"#);
    assert_kind(&r, "protocol");
    // Negative node id: must be a typed error, not a write to node 0
    // (the old `as usize` cast saturated -1 to 0).
    let r = c.call(r#"{"op":"observe","node":-1,"y":0.5}"#);
    assert_kind(&r, "protocol");
    let r = c.call(r#"{"op":"predict","nodes":[-3]}"#);
    assert_kind(&r, "protocol");
    // Fault injection is off by default: the op is refused, not run.
    let r = c.call(r#"{"op":"fault","mode":"panic"}"#);
    assert_kind(&r, "protocol");

    // Same connection still serves real traffic afterwards.
    let p = c.call(r#"{"op":"predict","nodes":[0,1],"samples":4}"#);
    assert_ok(&p);
    assert_eq!(p.get("mean").unwrap().as_arr().unwrap().len(), 2);
    let s = c.call(r#"{"op":"stats"}"#);
    assert_ok(&s);
    // The rejected negative-node observe must not have landed anywhere.
    assert_eq!(s.get("n_obs").unwrap().as_usize(), Some(0), "{s:?}");

    assert_ok(&c.call(r#"{"op":"shutdown"}"#));
    join_within(handle, Duration::from_secs(20), "after malformed battery");
}

#[test]
fn frames_assembled_from_byte_sized_reads() {
    let (addr, handle) = start_server_with(64, quick_config());
    let mut c = Client::connect(addr);
    // Trickle a request one byte at a time: chunk boundaries must be
    // invisible to the protocol.
    let body = br#"{"op":"predict","nodes":[0,1],"samples":4}"#;
    for &byte in body.iter() {
        c.stream.write_all(&[byte]).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    c.stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    c.reader.read_line(&mut line).unwrap();
    let p = Json::parse(&line).unwrap();
    assert_ok(&p);
    assert_eq!(p.get("mean").unwrap().as_arr().unwrap().len(), 2);
    assert_ok(&c.call(r#"{"op":"shutdown"}"#));
    join_within(handle, Duration::from_secs(20), "after byte-sized reads");
}

#[test]
fn mid_frame_disconnects_leave_server_healthy() {
    let (addr, handle) = start_server_with(64, quick_config());
    // Several clients die mid-frame (no newline ever sent).
    for k in 0..4 {
        let mut s = TcpStream::connect(addr).unwrap();
        let partial = format!(r#"{{"op":"predict","nodes":[{k},"#);
        s.write_all(partial.as_bytes()).unwrap();
        drop(s);
    }
    // And one dies mid-frame with garbage.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&[0x00, 0xFF, b'{', b'[']).unwrap();
    drop(s);

    let mut c = Client::connect(addr);
    for i in 0..3 {
        let r = c.call(&format!(
            r#"{{"op":"observe","node":{},"y":{}}}"#,
            i * 7,
            i as f64 * 0.25
        ));
        assert_ok(&r);
    }
    let p = c.call(r#"{"op":"predict","nodes":[0,7,14],"samples":4}"#);
    assert_ok(&p);
    assert_ok(&c.call(r#"{"op":"shutdown"}"#));
    // Joining proves the half-dead connections' threads exited too.
    join_within(handle, Duration::from_secs(20), "after mid-frame disconnects");
}

// ---------------------------------------------------------------------
// 3. Lifecycle faults
// ---------------------------------------------------------------------

#[test]
fn shutdown_completes_with_idle_connection_attached() {
    let (addr, handle) = start_server_with(64, quick_config());
    // An idle client that never sends a byte — the old reader blocked
    // in `lines()` forever here and `thread::scope` never joined.
    let idle = TcpStream::connect(addr).unwrap();
    let mut c = Client::connect(addr);
    let bye = c.call(r#"{"op":"shutdown"}"#);
    assert_ok(&bye);
    join_within(
        handle,
        Duration::from_secs(20),
        "shutdown must complete with an idle client attached",
    );
    drop(idle);
}

/// Outcome of connecting while the server may be at capacity.
enum Probe {
    /// Got the unsolicited busy line.
    Rejected(Json),
    /// Accepted (no busy line within the probe window).
    Accepted(Client),
}

/// Connect and wait briefly for an unsolicited reply: a capped server
/// sends its `overload` line immediately; an accepted connection sends
/// nothing until asked. (The probe never writes first — writing into a
/// just-rejected socket can turn the pending busy line into a reset.)
fn probe(addr: std::net::SocketAddr) -> Probe {
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_millis(300)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => panic!("server closed a probe without any reply line"),
        Ok(_) => Probe::Rejected(Json::parse(&line).unwrap()),
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            stream.set_read_timeout(None).unwrap();
            Probe::Accepted(Client { stream, reader })
        }
        Err(e) => panic!("probe read failed: {e}"),
    }
}

#[test]
fn connection_cap_rejects_gracefully_and_recovers() {
    let config = ServerConfig { max_connections: 2, ..quick_config() };
    let (addr, handle) = start_server_with(64, config);
    let mut a = Client::connect(addr);
    let mut b = Client::connect(addr);
    // A served round-trip pins both connections as accepted before the
    // third connect.
    assert_ok(&a.call(r#"{"op":"stats"}"#));
    assert_ok(&b.call(r#"{"op":"stats"}"#));

    // Third connection: one graceful busy line, classified overload.
    match probe(addr) {
        Probe::Rejected(r) => {
            assert_kind(&r, "overload");
            assert!(
                r.get("error").unwrap().as_str().unwrap().contains("busy"),
                "{r:?}"
            );
        }
        Probe::Accepted(_) => panic!("third connection must be rejected"),
    }

    // Dropping a client frees its slot (within a read-timeout tick).
    drop(a);
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut admitted = loop {
        match probe(addr) {
            Probe::Accepted(c) => break c,
            Probe::Rejected(r) => {
                assert_kind(&r, "overload");
                assert!(
                    Instant::now() < deadline,
                    "slot never reclaimed after disconnect"
                );
                std::thread::sleep(Duration::from_millis(30));
            }
        }
    };
    assert_ok(&admitted.call(r#"{"op":"stats"}"#));
    assert_ok(&admitted.call(r#"{"op":"shutdown"}"#));
    join_within(handle, Duration::from_secs(20), "after connection-cap test");
}

#[test]
fn panicking_handler_is_isolated_and_lock_poison_recovered() {
    let config = ServerConfig { fault_injection: true, ..quick_config() };
    let (addr, handle) = start_server_with(64, config);
    let mut a = Client::connect(addr);

    // Plain handler panic: internal error on this connection, which
    // then keeps working.
    let r = a.call(r#"{"op":"fault","mode":"panic"}"#);
    assert_kind(&r, "internal");
    assert!(
        r.get("error").unwrap().as_str().unwrap().contains("injected fault"),
        "{r:?}"
    );
    assert_ok(&a.call(r#"{"op":"stats"}"#));

    // Panic while holding the model lock: the mutex is poisoned
    // mid-handler; lock recovery must keep every other path serving.
    let r = a.call(r#"{"op":"fault","mode":"panic_locked"}"#);
    assert_kind(&r, "internal");
    let mut b = Client::connect(addr);
    assert_ok(&b.call(r#"{"op":"observe","node":3,"y":0.5}"#));
    let p = b.call(r#"{"op":"predict","nodes":[0,3],"samples":4}"#);
    assert_ok(&p);
    assert_eq!(p.get("mean").unwrap().as_arr().unwrap().len(), 2);
    // Repeat on the original (panicking) connection too.
    assert_ok(&a.call(r#"{"op":"stats"}"#));

    assert_ok(&b.call(r#"{"op":"shutdown"}"#));
    join_within(handle, Duration::from_secs(20), "after handler panics");
}

// ---------------------------------------------------------------------
// Bitwise isolation: a well-behaved client vs a fault storm
// ---------------------------------------------------------------------

/// One fixed request script; returns the raw reply lines byte-for-byte.
fn well_behaved_session(addr: std::net::SocketAddr) -> Vec<String> {
    let mut c = Client::connect(addr);
    let mut lines = Vec::new();
    for i in 0..5usize {
        lines.push(c.call_raw(
            format!(
                r#"{{"op":"observe","node":{},"y":{}}}"#,
                i * 10,
                (i as f64 * 0.7).sin()
            )
            .as_bytes(),
        ));
    }
    lines.push(
        c.call_raw(br#"{"op":"predict","nodes":[0,25,49],"samples":4}"#),
    );
    lines.push(c.call_raw(br#"{"op":"predict","nodes":[7,13],"samples":8}"#));
    lines
}

#[test]
fn predictions_bitwise_identical_under_fault_storm() {
    let config = ServerConfig {
        wire: WireConfig {
            max_frame_bytes: 2048,
            max_parse_depth: 16,
            unicode: UnicodeMode::Strict,
        },
        ..quick_config()
    };

    // Reference run: no faults anywhere.
    let (addr, handle) = start_server_with(64, config.clone());
    let clean = well_behaved_session(addr);
    assert_ok(&Client::connect(addr).call(r#"{"op":"shutdown"}"#));
    join_within(handle, Duration::from_secs(20), "reference run");

    // Storm run: same server parameters, same seed, plus three chaos
    // clients hammering garbage, oversize frames, and mid-frame
    // disconnects for the whole session.
    let (addr, handle) = start_server_with(64, config);
    let stop = Arc::new(AtomicBool::new(false));
    let chaos: Vec<_> = (0..3)
        .map(|k| {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + k);
                while !stop.load(Ordering::Relaxed) {
                    let Ok(mut s) = TcpStream::connect(addr) else {
                        continue;
                    };
                    match rng.below(3) {
                        0 => {
                            // Binary garbage frame; replies ignored.
                            let _ = s.write_all(b"\xff\x00garbage{{{[\n");
                        }
                        1 => {
                            // Frame-cap bomb (4 KiB against a 2 KiB cap).
                            let junk = vec![b'['; 4096];
                            let _ = s.write_all(&junk);
                            let _ = s.write_all(b"\n");
                        }
                        _ => {
                            // Mid-frame disconnect.
                            let _ = s.write_all(br#"{"op":"predict","nodes":[0"#);
                        }
                    }
                    drop(s);
                    std::thread::sleep(Duration::from_millis(1));
                }
            })
        })
        .collect();
    // Let the storm actually rage before (and during) the session.
    std::thread::sleep(Duration::from_millis(50));
    let stormy = well_behaved_session(addr);
    stop.store(true, Ordering::Relaxed);
    for h in chaos {
        h.join().unwrap();
    }
    assert_eq!(
        clean, stormy,
        "well-behaved client's bytes diverged under the fault storm"
    );
    assert_ok(&Client::connect(addr).call(r#"{"op":"shutdown"}"#));
    join_within(handle, Duration::from_secs(20), "storm run");
}
