//! Integration: the PJRT artifact path (L1 Pallas + L2 JAX, AOT-lowered)
//! must agree numerically with the native Rust engine on the same GRF
//! features. This is the cross-layer contract of the whole stack.
//!
//! Requires `artifacts/` (run `make artifacts` first); tests are skipped
//! gracefully if the directory is missing so `cargo test` works in a
//! fresh checkout.

use grfgp::gp::{GpModel, Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::linalg::Mat;
use grfgp::runtime::Runtime;
use grfgp::util::rng::Rng;
use grfgp::walks::{sample_components, WalkConfig};
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
        return None;
    }
    // Also skip when artifacts exist but the executor can't come up —
    // in particular the default build, where the `pjrt` feature is off
    // and Runtime is the always-erroring stub.
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts present but runtime unavailable ({e})");
            None
        }
    }
}

/// Build a small GRF model + its ELL representation.
fn setup(seed: u64) -> (GpModel, grfgp::sparse::EllArtifact, grfgp::sparse::EllArtifact) {
    let g = generators::grid2d(10, 10);
    let cfg = WalkConfig { n_walks: 24, max_len: 3, threads: 1, ..Default::default() };
    let comps = sample_components(&g, &cfg, seed);
    let mut rng = Rng::new(seed);
    let train: Vec<usize> = rng.sample_without_replacement(100, 40);
    let y: Vec<f64> = train.iter().map(|&i| (i as f64 * 0.17).sin()).collect();
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.25);
    let model = GpModel::new(comps, hypers, &train, &y);
    let phi = model.features.current();
    let width = phi.max_row_nnz();
    let phi_t = phi.transpose();
    let width_t = phi_t.max_row_nnz();
    let ell = phi.to_ell_artifact(width).unwrap();
    let ell_t = phi_t.to_ell_artifact(width_t).unwrap();
    (model, ell, ell_t)
}

#[test]
fn gram_matvec_pjrt_matches_native() {
    let Some(rt) = runtime() else { return };
    let (model, ell, ell_t) = setup(1);
    let n = model.n();
    let mut rng = Rng::new(9);
    let x64: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();

    let native = {
        let mut v = model.apply_kernel(&x64);
        for (vi, xi) in v.iter_mut().zip(&x64) {
            *vi += 0.25 * xi;
        }
        v
    };
    let pjrt = rt
        .gram_matvec(&ell, &ell_t, &x32, 0.25)
        .expect("pjrt gram_matvec");
    for i in 0..n {
        assert!(
            (pjrt[i] as f64 - native[i]).abs() < 1e-3 * (1.0 + native[i].abs()),
            "node {i}: pjrt {} vs native {}",
            pjrt[i],
            native[i]
        );
    }
}

#[test]
fn cg_solve_pjrt_matches_native() {
    let Some(rt) = runtime() else { return };
    let (model, ell, ell_t) = setup(2);
    let n = model.n();
    let mask32: Vec<f32> = model.mask.iter().map(|&m| m as f32).collect();
    let rhs64: Vec<f64> = model
        .mask
        .iter()
        .zip(&model.y)
        .map(|(m, y)| m * y)
        .collect();
    let rhs32: Vec<f32> = rhs64.iter().map(|&v| v as f32).collect();

    let (native, st) = model.solve_system(&rhs64);
    assert!(st.converged);
    let (pjrt, rs) = rt
        .cg_solve(&ell, &ell_t, &mask32, &[rhs32], 0.25)
        .expect("pjrt cg_solve");
    assert!(rs[0] < 1e-4, "artifact CG residual {rs:?}");
    for i in 0..n {
        assert!(
            (pjrt[0][i] as f64 - native[i]).abs() < 5e-3 * (1.0 + native[i].abs()),
            "node {i}: pjrt {} vs native {}",
            pjrt[0][i],
            native[i]
        );
    }
}

#[test]
fn posterior_mean_pjrt_matches_native() {
    let Some(rt) = runtime() else { return };
    let (model, ell, ell_t) = setup(3);
    let n = model.n();
    let mask32: Vec<f32> = model.mask.iter().map(|&m| m as f32).collect();
    let y32: Vec<f32> = model.y.iter().map(|&v| v as f32).collect();

    let (native, _) = model.posterior_mean();
    let pjrt = rt
        .posterior_mean(&ell, &ell_t, &mask32, &y32, 0.25)
        .expect("pjrt posterior_mean");
    for i in 0..n {
        assert!(
            (pjrt[i] as f64 - native[i]).abs() < 5e-3 * (1.0 + native[i].abs()),
            "node {i}: pjrt {} vs native {}",
            pjrt[i],
            native[i]
        );
    }
}

#[test]
fn posterior_sample_pjrt_matches_native_formula() {
    let Some(rt) = runtime() else { return };
    let (model, ell, ell_t) = setup(4);
    let n = model.n();
    let mut rng = Rng::new(77);
    let w: Vec<f64> = rng.normal_vec(n);
    let eps: Vec<f64> = (0..n).map(|_| 0.5 * rng.normal()).collect();
    let mask32: Vec<f32> = model.mask.iter().map(|&m| m as f32).collect();
    let y32: Vec<f32> = model.y.iter().map(|&v| v as f32).collect();
    let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
    let eps32: Vec<f32> = eps.iter().map(|&v| v as f32).collect();

    // Native pathwise formula with the same (w, eps).
    let phi = model.features.current();
    let g = phi.matvec(&w);
    let rhs: Vec<f64> = (0..n)
        .map(|i| model.mask[i] * (model.y[i] - g[i] - eps[i]))
        .collect();
    let (alpha, _) = model.solve_system(&rhs);
    let malpha: Vec<f64> = (0..n).map(|i| model.mask[i] * alpha[i]).collect();
    let corr = model.apply_kernel(&malpha);
    let native: Vec<f64> = (0..n).map(|i| g[i] + corr[i]).collect();

    let pjrt = rt
        .posterior_sample(&ell, &ell_t, &mask32, &y32, &w32, &eps32, 0.25)
        .expect("pjrt posterior_sample");
    for i in 0..n {
        assert!(
            (pjrt[i] as f64 - native[i]).abs() < 1e-2 * (1.0 + native[i].abs()),
            "node {i}: pjrt {} vs native {}",
            pjrt[i],
            native[i]
        );
    }
}

#[test]
fn dense_diffusion_pjrt_matches_native_expm() {
    let Some(rt) = runtime() else { return };
    let g = generators::ring(64);
    let n = 64;
    let w_dense = g.dense_adjacency();
    let mut w32 = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            w32[i * n + j] = w_dense[i][j] as f32;
        }
    }
    let beta = 0.5f32;
    let k = rt
        .dense_diffusion(&w32, n, beta, 1.0)
        .expect("pjrt dense_diffusion");
    let l = Mat::from_rows(&g.dense_laplacian());
    let expect = grfgp::linalg::expm::diffusion_kernel(&l, beta as f64, 1.0);
    for i in 0..n {
        for j in 0..n {
            assert!(
                (k[i * n + j] as f64 - expect[(i, j)]).abs() < 1e-3,
                "({i},{j}): {} vs {}",
                k[i * n + j],
                expect[(i, j)]
            );
        }
    }
}
