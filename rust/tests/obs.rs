//! Integration: the telemetry surface. Proves the registry's contracts
//! from the outside — the record path performs **zero heap
//! allocations** (counting global allocator), the predict path takes
//! **zero model locks** with telemetry enabled, per-op request counters
//! are **exact** under concurrent mixed traffic (no lost or double
//! counts), scrapes taken mid-traffic are internally consistent
//! (`count == Σ buckets` per histogram), every wire response — errors
//! included — carries a distinct `trace_id`, and both export formats
//! (JSON schema, Prometheus text) hold their shape.

use grfgp::gp::{Hypers, Modulation};
use grfgp::graph::generators;
use grfgp::obs::registry;
use grfgp::obs::span::Span;
use grfgp::server::batcher::{Request, Response};
use grfgp::server::wire::ErrorKind;
use grfgp::server::{
    handle, slow_request_record, ModelState, ServerConfig, ServerState,
};
use grfgp::stream::StreamingFeatures;
use grfgp::util::json::Json;
use grfgp::walks::WalkConfig;
use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

// ---------------------------------------------------------------------
// Counting allocator: every heap allocation in this test binary bumps
// ALLOCS, which is how the zero-allocation contract of the record path
// is *proved* rather than asserted by inspection.

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// Edition 2021: bodies of `unsafe fn` may use unsafe operations
// directly; the forwarding calls below inherit System's contracts.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------
// The registry is process-global, so tests in this binary that record
// into it (or read deltas from it) must not interleave. This is the
// integration-test twin of the library's internal `test_lock` (which
// is `cfg(test)`-only and not visible here).

fn lock() -> MutexGuard<'static, ()> {
    static M: Mutex<()> = Mutex::new(());
    M.lock().unwrap_or_else(PoisonError::into_inner)
}

// ---------------------------------------------------------------------
// Harness (mirrors tests/server.rs).

fn state(n: usize, seed: u64) -> ServerState {
    let g = generators::ring(n);
    let cfg = WalkConfig {
        n_walks: 16,
        p_halt: 0.1,
        max_len: 3,
        threads: 1,
        ..Default::default()
    };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
    let stream =
        StreamingFeatures::new(g, cfg, hypers.modulation.coeffs(), 0);
    ServerState::new(
        ModelState::new(stream, hypers, seed),
        ServerConfig::default(),
    )
}

fn start_server(
    n: usize,
) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let g = generators::ring(n);
    let cfg = WalkConfig {
        n_walks: 32,
        p_halt: 0.1,
        max_len: 3,
        threads: 1,
        ..Default::default()
    };
    let hypers = Hypers::new(Modulation::diffusion(1.0, 1.0, 3), 0.1);
    let stream = StreamingFeatures::new(g, cfg, hypers.modulation.coeffs(), 0);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        grfgp::server::ServeOptions::new()
            .seed(7)
            .serve_on(stream, hypers, listener)
            .unwrap();
    });
    (addr, server)
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn call(&mut self, body: &str) -> Json {
        self.stream.write_all(body.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(&line).expect("server must return valid JSON")
    }
}

fn trace_of(r: &Json) -> String {
    r.get("trace_id")
        .and_then(Json::as_str)
        .unwrap_or_else(|| panic!("response must carry a trace_id: {r:?}"))
        .to_string()
}

/// The per-histogram no-torn-reads contract: an exported `count` always
/// equals the sum of the bucket counts exported next to it, even when
/// the scrape raced live traffic.
fn assert_histograms_consistent(metrics: &Json) {
    let Some(Json::Obj(histos)) = metrics.get("histograms") else {
        panic!("metrics.histograms must be an object: {metrics:?}");
    };
    for (name, h) in histos {
        let count = h
            .get("count")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("histogram {name} missing count"))
            as u64;
        let total: u64 = h
            .get("buckets")
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("histogram {name} missing buckets"))
            .iter()
            .map(|b| {
                b.as_arr().expect("bucket pair")[1]
                    .as_f64()
                    .expect("bucket count") as u64
            })
            .sum();
        assert_eq!(
            count, total,
            "histogram {name}: exported count must equal Σ buckets"
        );
    }
}

// ---------------------------------------------------------------------

#[test]
fn record_path_performs_zero_heap_allocations() {
    let _g = lock();
    registry::set_enabled(true);
    // Warm-up (first Instant::now may touch lazily-initialised state).
    registry::STOPWATCH_NS.record(1);
    drop(Span::new(&registry::COMPACT_NS));

    // The test harness itself may allocate on other threads (printing
    // a finished test's result line), so measure over several windows:
    // a record path that allocates does so deterministically on every
    // iteration and can never produce a clean window.
    let mut clean = false;
    for _ in 0..16 {
        let before = alloc_count();
        for i in 0..10_000u64 {
            registry::STOPWATCH_NS.record(i & 0xFFF);
            registry::STOPWATCH_NS
                .record_duration(Duration::from_nanos(i & 0x3FF));
            registry::CG_SOLVES.inc();
            registry::CG_LAST_RESIDUAL.set(i as f64);
            let span = Span::new(&registry::COMPACT_NS);
            drop(span);
        }
        if alloc_count() == before {
            clean = true;
            break;
        }
    }
    assert!(
        clean,
        "counter/gauge/histogram/span record path must not allocate"
    );
}

#[test]
fn predict_path_takes_zero_model_locks_with_telemetry_on() {
    let _g = lock();
    registry::set_enabled(true);
    let state = state(128, 3);
    // One write so predicts run off a post-write published snapshot.
    let observe =
        Request::parse(r#"{"op":"observe","node":5,"y":0.5}"#).unwrap();
    assert!(handle(&state, &observe).ok);

    let locks_before = state.model_lock_acquisitions.load(Ordering::SeqCst);
    let lag_before = registry::PREDICT_SNAPSHOT_LAG_NS.count();
    for k in 0..12 {
        let req = Request::Predict { nodes: vec![k, k + 1], samples: 2 };
        let r = handle(&state, &req);
        assert!(r.ok, "{:?}", r.fields);
    }
    assert_eq!(
        state.model_lock_acquisitions.load(Ordering::SeqCst),
        locks_before,
        "predicts must stay wait-free with telemetry enabled"
    );
    assert_eq!(
        registry::PREDICT_SNAPSHOT_LAG_NS.count() - lag_before,
        12,
        "each predict engine call records its snapshot lag"
    );
}

#[test]
fn metrics_op_json_schema() {
    let _g = lock();
    registry::set_enabled(true);
    let state = state(64, 1);
    let r = handle(&state, &Request::Metrics { prometheus: false });
    assert!(r.ok);
    let j = r.to_json();

    let metrics = j.get("metrics").expect("metrics key");
    for name in [
        "req_predict",
        "req_observe",
        "errors_parse",
        "slow_requests",
        "cg_solves",
        "spmm_ell",
        "stream_delta_batches",
        "snapshot_publishes",
    ] {
        assert!(
            metrics.path(&["counters", name]).is_some(),
            "missing counter {name}"
        );
    }
    for name in [
        "grf_variance_iid",
        "grf_variance_antithetic",
        "grf_variance_qmc",
        "cg_last_residual",
    ] {
        assert!(
            metrics.path(&["gauges", name]).is_some(),
            "missing gauge {name}"
        );
    }
    for name in [
        "request_ns_predict",
        "cg_iters",
        "spmv_ell_ns",
        "resample_ns",
        "compact_ns",
        "snapshot_publish_ns",
        "predict_snapshot_lag_ns",
    ] {
        let h = metrics
            .path(&["histograms", name])
            .unwrap_or_else(|| panic!("missing histogram {name}"));
        for key in ["unit", "count", "sum", "p50", "p95", "p99", "buckets"] {
            assert!(h.get(key).is_some(), "histogram {name} missing {key}");
        }
    }
    assert_histograms_consistent(metrics);

    for key in [
        "requests",
        "graph_version",
        "published_snapshots",
        "predicts_served",
        "model_lock_acquisitions",
        "active_connections",
        "n_nodes",
        "telemetry_enabled",
    ] {
        assert!(
            j.path(&["server", key]).is_some(),
            "missing server.{key}"
        );
    }
    assert_eq!(
        j.path(&["server", "telemetry_enabled"]).unwrap().as_bool(),
        Some(true)
    );
}

#[test]
fn metrics_op_prometheus_export_is_well_formed() {
    let _g = lock();
    registry::set_enabled(true);
    // Non-trivial histogram content so the bucket triples render.
    registry::STOPWATCH_NS.record(123);
    registry::CG_SOLVES.inc();
    let state = state(32, 2);
    let r = handle(&state, &Request::Metrics { prometheus: true });
    assert!(r.ok);
    let j = r.to_json();
    assert_eq!(
        j.get("format").and_then(Json::as_str),
        Some("prometheus")
    );
    let text = j.get("text").and_then(Json::as_str).expect("text");
    grfgp::obs::prom::validate(text)
        .expect("prometheus rendering must validate");
    assert!(text.contains("# TYPE grfgp_req_predict counter"));
    assert!(text.contains("# TYPE grfgp_grf_variance_iid gauge"));
    assert!(text.contains("# TYPE grfgp_grf_variance_qmc gauge"));
    assert!(text.contains("grfgp_stopwatch_ns_bucket{le=\"+Inf\"}"));
    assert!(text.contains("grfgp_stopwatch_ns_count"));
}

#[test]
fn slow_request_log_record_shape() {
    let rec = slow_request_record(
        "predict",
        Duration::from_millis(42),
        "7-2a",
        &Response::fault(ErrorKind::Internal, "boom"),
    );
    assert_eq!(rec.get("slow_request").unwrap().as_bool(), Some(true));
    assert_eq!(rec.get("op").unwrap().as_str(), Some("predict"));
    assert!(rec.get("ms").unwrap().as_f64().unwrap() >= 42.0);
    assert_eq!(rec.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(rec.get("error_kind").unwrap().as_str(), Some("internal"));
    assert_eq!(rec.get("trace_id").unwrap().as_str(), Some("7-2a"));
    // The outlier log is line-oriented: one record, one line.
    assert!(!rec.to_string().contains('\n'));
}

#[test]
fn mixed_traffic_counts_are_exact_and_traced() {
    let _g = lock();
    registry::set_enabled(true);
    let (addr, server) = start_server(256);

    let predict0 = registry::REQ_PREDICT.get();
    let predict_lat0 = registry::REQUEST_NS_PREDICT.count();
    let observe0 = registry::REQ_OBSERVE.get();
    let add0 = registry::REQ_ADD_EDGE.get();
    let rm0 = registry::REQ_REMOVE_EDGE.get();
    let stats0 = registry::REQ_STATS.get();
    let metrics0 = registry::REQ_METRICS.get();
    let parse0 = registry::ERR_PARSE.get();
    let proto0 = registry::ERR_PROTOCOL.get();

    let mut traces: Vec<String> = Vec::new();
    let mut c = Client::connect(addr);
    for i in 0..10 {
        let r = c.call(&format!(
            r#"{{"op":"observe","node":{},"y":{}}}"#,
            i * 20,
            (i as f64 * 0.3).sin()
        ));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        traces.push(trace_of(&r));
    }

    // Concurrent predict clients racing a metrics scraper: counts must
    // come out exact, and every scrape taken mid-flight must be
    // internally consistent.
    let predictors: Vec<_> = (0..3)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let mut ids = Vec::new();
                for k in 0..8 {
                    let r = c.call(&format!(
                        r#"{{"op":"predict","nodes":[{}],"samples":2}}"#,
                        t * 50 + k
                    ));
                    assert_eq!(
                        r.get("ok").unwrap().as_bool(),
                        Some(true),
                        "{r:?}"
                    );
                    ids.push(trace_of(&r));
                }
                ids
            })
        })
        .collect();
    let scraper = std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        let mut ids = Vec::new();
        for _ in 0..20 {
            let r = c.call(r#"{"op":"metrics"}"#);
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
            assert_histograms_consistent(r.get("metrics").expect("metrics"));
            ids.push(trace_of(&r));
        }
        ids
    });
    for h in predictors {
        traces.extend(h.join().unwrap());
    }
    traces.extend(scraper.join().unwrap());

    // Graph deltas + stats from the original client.
    for (u, v) in [(0usize, 5usize), (1, 9), (2, 17)] {
        let r =
            c.call(&format!(r#"{{"op":"add_edge","u":{u},"v":{v},"w":0.5}}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        traces.push(trace_of(&r));
    }
    for (u, v) in [(0usize, 5usize), (1, 9)] {
        let r = c.call(&format!(r#"{{"op":"remove_edge","u":{u},"v":{v}}}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        traces.push(trace_of(&r));
    }
    for _ in 0..2 {
        let r = c.call(r#"{"op":"stats"}"#);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        traces.push(trace_of(&r));
    }

    // Malformed traffic: wire-level parse errors and unknown ops are
    // counted by kind and still traced.
    let bad = c.call("this is not json");
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(bad.get("error_kind").unwrap().as_str(), Some("parse"));
    traces.push(trace_of(&bad));
    let unknown = c.call(r#"{"op":"zap"}"#);
    assert_eq!(unknown.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(
        unknown.get("error_kind").unwrap().as_str(),
        Some("protocol")
    );
    traces.push(trace_of(&unknown));

    // Wire-level wait-free check: two scrapes with only predicts in
    // between must report the same model-lock acquisition count.
    let m0 = c.call(r#"{"op":"metrics"}"#);
    let locks0 = m0
        .path(&["server", "model_lock_acquisitions"])
        .and_then(Json::as_f64)
        .unwrap();
    for k in 0..8 {
        let r = c.call(&format!(
            r#"{{"op":"predict","nodes":[{k}],"samples":2}}"#
        ));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        traces.push(trace_of(&r));
    }
    let m1 = c.call(r#"{"op":"metrics"}"#);
    let locks1 = m1
        .path(&["server", "model_lock_acquisitions"])
        .and_then(Json::as_f64)
        .unwrap();
    assert_eq!(
        locks0, locks1,
        "predicts over the wire must take zero model locks"
    );
    traces.push(trace_of(&m0));
    traces.push(trace_of(&m1));

    // Exact deltas: no lost counts, no double counts, batched or not.
    assert_eq!(registry::REQ_PREDICT.get() - predict0, 32);
    assert_eq!(registry::REQUEST_NS_PREDICT.count() - predict_lat0, 32);
    assert_eq!(registry::REQ_OBSERVE.get() - observe0, 10);
    assert_eq!(registry::REQ_ADD_EDGE.get() - add0, 3);
    assert_eq!(registry::REQ_REMOVE_EDGE.get() - rm0, 2);
    assert_eq!(registry::REQ_STATS.get() - stats0, 2);
    assert_eq!(registry::REQ_METRICS.get() - metrics0, 22);
    assert_eq!(registry::ERR_PARSE.get() - parse0, 1);
    assert_eq!(registry::ERR_PROTOCOL.get() - proto0, 1);

    // Every response carried its own trace id.
    let unique: HashSet<&str> = traces.iter().map(String::as_str).collect();
    assert_eq!(
        unique.len(),
        traces.len(),
        "trace ids must be distinct per dispatched frame"
    );

    // Clean shutdown so no server thread outlives the registry lock.
    let bye = c.call(r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").unwrap().as_bool(), Some(true));
    server.join().unwrap();
}
